//! # cupso — queue-based parallel Particle Swarm Optimization
//!
//! Reproduction of *"cuPSO: GPU Parallelization for Particle Swarm
//! Optimization Algorithms"* (Wang, Ho, Tu, Hung — ACM SAC'22) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The library is organised in three planes (see `DESIGN.md`):
//!
//! * **Plane A** — the paper's five algorithms (serial CPU, parallel
//!   Reduction, Loop-Unrolling, Queue, Queue-Lock) executed on a CUDA-like
//!   grid/block substrate over OS threads ([`exec`], [`engine`], [`pso`]).
//! * **Plane B** — the three-layer AOT stack: Pallas kernels + JAX scan
//!   model lowered to HLO text at build time, loaded and driven from Rust
//!   via PJRT ([`runtime`], [`coordinator`]).
//! * **Plane C** — an analytical GTX-1080Ti cost model that regenerates the
//!   paper's absolute-shaped tables ([`gpusim`]).
//!
//! On top of Plane A sits the **execution stack**: every engine is a
//! step-wise solver ([`engine::Engine::prepare`] → [`engine::Run`]), and
//! the [`scheduler`] multiplexes many concurrent jobs over one shared
//! worker pool with per-job termination criteria (the `cupso batch`
//! subcommand drives it from a multi-job TOML). Runs are additionally
//! **checkpointable** ([`engine::Run::checkpoint`] /
//! [`engine::Engine::restore`], serialized by [`checkpoint`]): the
//! scheduler can preempt a live job to a checkpoint and resume it later —
//! on a different stream, or in a different process via
//! `cupso batch --checkpoint-dir` + `cupso resume` — bit-identically for
//! the bit-exact engines. The [`service`] layer turns that scheduler
//! into a long-lived daemon (`cupso serve`): jobs are submitted,
//! cancelled and watched over a Unix-socket JSON protocol while the
//! session runs, and `drain` checkpoints all live work into a snapshot
//! that `cupso resume` continues.
//!
//! ## Quickstart
//!
//! ```no_run
//! use cupso::fitness::{Cubic, Objective};
//! use cupso::pso::PsoParams;
//! use cupso::engine::{Engine, ParallelSettings, QueueLockEngine};
//!
//! let params = PsoParams::paper_1d(1024, 10_000);
//! let mut engine = QueueLockEngine::new(ParallelSettings::with_workers(4));
//! let out = engine.run(&params, &Cubic, Objective::Maximize, 42);
//! println!("gbest fitness = {:.6} at {:?}", out.gbest_fit, out.gbest_pos);
//! ```

// The unsafe hot path (exec primitives, executor slots) is audited: every
// unsafe operation carries its own `// SAFETY:` justification, enforced
// by this lint plus `scripts/unsafe_audit.sh` in CI.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod benchkit;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod exec;
pub mod fitness;
pub mod gpusim;
pub mod metrics;
pub mod modelcheck;
pub mod pso;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod service;
pub mod telemetry;
pub mod testsupport;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
