//! The virtual scheduler and the vector-clock race detector (compiled
//! only under `--cfg cupso_model`).
//!
//! ## Serialization discipline
//!
//! Model threads are real OS threads, but at most one is ever *running*:
//! every instrumented operation ([`atomic_access`], [`data_read`],
//! [`data_write`], [`voluntary_yield`]) is a **rendezvous** — the thread
//! parks as `Ready` and proceeds only when the controller grants it the
//! turn. The controller (the exploring test thread) waits until every
//! thread is parked, picks one `Ready` thread per the schedule under
//! exploration, and grants exactly that thread one step (the granted
//! operation plus the uninstrumented code up to its next rendezvous).
//! Interleavings are therefore explored at atomic-op granularity, and a
//! (schedule, scenario) pair replays deterministically — the property the
//! DFS backtracker in [`super::Explorer`] relies on.
//!
//! ## Happens-before tracking
//!
//! Each thread carries a vector clock; each atomic location carries a
//! *sync clock* standing for the release history readable through it:
//!
//! * store with Release ⇒ the location's sync clock becomes the storing
//!   thread's clock (a new release-sequence head);
//! * store without Release ⇒ the sync clock is cleared (the relaxed
//!   store breaks the release sequence — this is exactly what the
//!   `SpinLock::unlock` mutation test relies on);
//! * RMW ⇒ joins its clock *into* the sync clock when it releases, and
//!   leaves the sync clock intact otherwise (an RMW continues the
//!   release sequence per C++11 §[intro.races]);
//! * load/RMW with Acquire ⇒ the thread's clock joins the sync clock.
//!
//! [`RacyCell`](crate::exec::sync::RacyCell) accesses are checked against
//! per-location read/write shadow clocks: an access unordered (by the
//! tracked happens-before) with a prior conflicting access is reported as
//! a data race. `SeqCst` contributes its acquire/release halves only
//! (documented under-approximation, see `exec::sync` docs).

use super::Race;
use std::any::Any;
use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Semantic shape of one atomic operation, resolved *after* the op ran
/// (a failed CAS is a load at the failure ordering).
pub(crate) enum AtomicAccess {
    Load { acq: bool },
    Store { rel: bool },
    Rmw { acq: bool, rel: bool },
}

#[derive(Clone, Debug)]
struct VClock(Vec<u64>);

impl VClock {
    fn new(n: usize) -> Self {
        VClock(vec![0; n])
    }

    fn join(&mut self, other: &VClock) {
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a = (*a).max(*b);
        }
    }

    fn get(&self, t: usize) -> u64 {
        self.0[t]
    }

    fn set(&mut self, t: usize, v: u64) {
        self.0[t] = v;
    }

    fn bump(&mut self, t: usize) {
        self.0[t] += 1;
    }
}

struct DataShadow {
    reads: VClock,
    writes: VClock,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Status {
    /// Parked at a rendezvous, eligible for a grant.
    Ready,
    /// Granted and executing up to its next rendezvous.
    Running,
    Finished,
}

struct TState {
    status: Status,
    /// Set while parked by a voluntary yield (`spin_loop`): the spinner
    /// made no progress, so the scheduler deprioritizes it.
    yielded: bool,
}

struct ExecState {
    threads: Vec<TState>,
    granted: Option<usize>,
    clocks: Vec<VClock>,
    /// Per-atomic-location sync (release-history) clock.
    atomics: HashMap<usize, VClock>,
    /// Per-data-location access shadow.
    data: HashMap<usize, DataShadow>,
    races: Vec<Race>,
    raced: HashSet<usize>,
    panics: Vec<Box<dyn Any + Send>>,
}

pub(crate) struct Runtime {
    state: Mutex<ExecState>,
    /// Controller waits here for quiescence (everyone parked/finished).
    ctrl_cv: Condvar,
    /// Model threads wait here for their grant.
    thread_cv: Condvar,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

#[derive(Clone)]
struct Ctx {
    rt: Arc<Runtime>,
    id: usize,
}

fn current_ctx() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

impl Runtime {
    fn new(n: usize) -> Self {
        Runtime {
            state: Mutex::new(ExecState {
                threads: (0..n)
                    .map(|_| TState {
                        status: Status::Running,
                        yielded: false,
                    })
                    .collect(),
                granted: None,
                clocks: (0..n).map(|_| VClock::new(n)).collect(),
                atomics: HashMap::new(),
                data: HashMap::new(),
                races: Vec::new(),
                raced: HashSet::new(),
                panics: Vec::new(),
            }),
            ctrl_cv: Condvar::new(),
            thread_cv: Condvar::new(),
        }
    }

    /// Park as Ready and block until granted; returns with the state
    /// lock held and this thread marked Running.
    fn rendezvous(&self, id: usize, voluntary: bool) -> MutexGuard<'_, ExecState> {
        let mut st = self.state.lock().unwrap();
        st.threads[id].status = Status::Ready;
        st.threads[id].yielded = voluntary;
        self.ctrl_cv.notify_all();
        while st.granted != Some(id) {
            st = self.thread_cv.wait(st).unwrap();
        }
        st.granted = None;
        st.threads[id].status = Status::Running;
        st.threads[id].yielded = false;
        st
    }

    fn finish_thread(&self, id: usize, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.threads[id].status = Status::Finished;
        if let Some(p) = panic {
            st.panics.push(p);
        }
        self.ctrl_cv.notify_all();
    }
}

impl ExecState {
    fn apply_atomic(&mut self, t: usize, addr: usize, access: AtomicAccess) {
        let n = self.clocks.len();
        let sync = self.atomics.entry(addr).or_insert_with(|| VClock::new(n));
        let clock = &mut self.clocks[t];
        match access {
            AtomicAccess::Load { acq } => {
                if acq {
                    clock.join(sync);
                }
            }
            AtomicAccess::Store { rel } => {
                *sync = if rel { clock.clone() } else { VClock::new(n) };
            }
            AtomicAccess::Rmw { acq, rel } => {
                if acq {
                    clock.join(sync);
                }
                if rel {
                    sync.join(clock);
                }
                // A non-releasing RMW leaves `sync` intact: it continues
                // the release sequence it read from.
            }
        }
        clock.bump(t);
    }

    fn apply_data(&mut self, t: usize, addr: usize, is_write: bool) {
        let n = self.clocks.len();
        let shadow = self.data.entry(addr).or_insert_with(|| DataShadow {
            reads: VClock::new(n),
            writes: VClock::new(n),
        });
        let clock = &self.clocks[t];
        let mut conflict = None;
        for u in 0..n {
            if u == t {
                continue;
            }
            if shadow.writes.get(u) > clock.get(u) {
                conflict = Some((u, "write"));
                break;
            }
            if is_write && shadow.reads.get(u) > clock.get(u) {
                conflict = Some((u, "read"));
                break;
            }
        }
        if let Some((u, other)) = conflict {
            if self.raced.insert(addr) {
                let mine = if is_write { "write" } else { "read" };
                self.races.push(Race {
                    desc: format!(
                        "data race at cell {addr:#x}: thread {t} {mine} is unordered \
                         with thread {u} {other}"
                    ),
                });
            }
        }
        // Record the access at a *post-bump* epoch: shadow entry 0 means
        // "never accessed", so a thread's first instrumented access must
        // record epoch 1, not 0 — the strict `>` checks above could
        // otherwise never fire against it (silent false negatives on any
        // race whose first side is a thread's first op). A release store
        // that follows publishes this post-bump clock (`apply_atomic`
        // clones before its own bump), so the epoch recorded here is
        // covered by the release and acquirers see the access as ordered.
        self.clocks[t].bump(t);
        let now = self.clocks[t].get(t);
        if is_write {
            shadow.writes.set(t, now);
        } else {
            shadow.reads.set(t, now);
        }
    }
}

/// Instrumented atomic op: rendezvous, run `f` while serialized, apply
/// its happens-before effect. Falls through to `f` outside explorations.
pub(crate) fn atomic_access<R>(addr: usize, f: impl FnOnce() -> (R, AtomicAccess)) -> R {
    match current_ctx() {
        None => f().0,
        Some(ctx) => {
            let mut st = ctx.rt.rendezvous(ctx.id, false);
            let (r, access) = f();
            st.apply_atomic(ctx.id, addr, access);
            r
        }
    }
}

/// Instrumented data-read event (no-op outside explorations).
pub(crate) fn data_read(addr: usize) {
    if let Some(ctx) = current_ctx() {
        let mut st = ctx.rt.rendezvous(ctx.id, false);
        st.apply_data(ctx.id, addr, false);
    }
}

/// Instrumented data-write event (no-op outside explorations).
pub(crate) fn data_write(addr: usize) {
    if let Some(ctx) = current_ctx() {
        let mut st = ctx.rt.rendezvous(ctx.id, false);
        st.apply_data(ctx.id, addr, true);
    }
}

/// Voluntary yield (`spin_loop`): a rendezvous that marks the thread as
/// making no progress, so the scheduler runs someone else next.
pub(crate) fn voluntary_yield() {
    match current_ctx() {
        None => std::hint::spin_loop(),
        Some(ctx) => {
            let _st = ctx.rt.rendezvous(ctx.id, true);
        }
    }
}

/// One decision the controller took: `taken` of `options` candidates.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Decision {
    pub options: usize,
    pub taken: usize,
}

/// How the controller picks at each decision point.
pub(crate) enum Mode<'a> {
    /// Replay `forced` choice indices, then first-option; record all
    /// decisions for the DFS backtracker.
    Dfs { forced: &'a [usize] },
    /// Uniform choice from a deterministic PRNG stream.
    Random {
        rng: &'a mut dyn FnMut(usize) -> usize,
    },
}

/// Knobs bounding one execution.
pub(crate) struct ScheduleCfg {
    /// Max preemptive switches (CHESS-style context bound).
    pub preemptions: u32,
    /// Branch points (decision points with ≥ 2 candidates) explored
    /// before falling back to fair round-robin (the execution still runs
    /// to completion, but stops branching and is reported as truncated).
    /// Forced moves — a lone Ready thread, spin echo rounds — cost
    /// nothing, so the budget measures real exploration depth.
    pub decision_budget: u64,
    /// Hard cap on fair-fallback grants; exceeding it means the scenario
    /// itself livelocks under fair scheduling and the run panics.
    pub fair_cap: u64,
}

pub(crate) struct ExecOutcome {
    pub decisions: Vec<Decision>,
    pub races: Vec<Race>,
    pub truncated: bool,
    pub panic: Option<Box<dyn Any + Send>>,
}

/// Run one scenario instance under one schedule to completion.
pub(crate) fn run_schedule(
    threads: Vec<Box<dyn FnOnce() + Send>>,
    cfg: &ScheduleCfg,
    mut mode: Mode<'_>,
) -> ExecOutcome {
    let n = threads.len();
    let rt = Arc::new(Runtime::new(n));
    let handles: Vec<_> = threads
        .into_iter()
        .enumerate()
        .map(|(i, f)| {
            let rt2 = rt.clone();
            std::thread::Builder::new()
                .name(format!("cupso-model-{i}"))
                .spawn(move || {
                    CTX.with(|c| {
                        *c.borrow_mut() = Some(Ctx {
                            rt: rt2.clone(),
                            id: i,
                        })
                    });
                    // The opening rendezvous: a thread becomes Ready
                    // before running any scenario code, so the very first
                    // user operation is already schedule-controlled.
                    drop(rt2.rendezvous(i, false));
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                    CTX.with(|c| *c.borrow_mut() = None);
                    rt2.finish_thread(i, res.err());
                })
                .expect("spawn model thread")
        })
        .collect();

    let mut decisions: Vec<Decision> = Vec::new();
    let mut branch_decisions = 0u64;
    let mut budget = cfg.preemptions;
    let mut current: Option<usize> = None;
    let mut truncated = false;
    let mut fair_grants = 0u64;
    {
        let mut st = rt.state.lock().unwrap();
        loop {
            while st.granted.is_some() || st.threads.iter().any(|t| t.status == Status::Running) {
                st = rt.ctrl_cv.wait(st).unwrap();
            }
            let ready: Vec<usize> = (0..n)
                .filter(|&i| st.threads[i].status == Status::Ready)
                .collect();
            if ready.is_empty() {
                break; // everyone finished
            }
            let pick = if truncated {
                // Fair deterministic fallback: round-robin. Spinners make
                // progress because whoever blocks them gets scheduled.
                fair_grants += 1;
                assert!(
                    fair_grants <= cfg.fair_cap,
                    "modelcheck: scenario did not terminate under fair scheduling \
                     (livelocked threads?)"
                );
                let start = current.map_or(0, |c| c + 1);
                (0..n)
                    .map(|k| (start + k) % n)
                    .find(|&i| st.threads[i].status == Status::Ready)
                    .expect("some thread is ready")
            } else {
                let options = compute_options(&st, current, &ready, budget);
                let taken = match &mut mode {
                    Mode::Dfs { forced } => {
                        let d = decisions.len();
                        if d < forced.len() {
                            forced[d].min(options.len() - 1)
                        } else {
                            0
                        }
                    }
                    Mode::Random { rng } => rng(options.len()),
                };
                decisions.push(Decision {
                    options: options.len(),
                    taken,
                });
                let pick = options[taken];
                let continuable = current.is_some_and(|c| {
                    st.threads[c].status == Status::Ready && !st.threads[c].yielded
                });
                if continuable && Some(pick) != current {
                    budget -= 1;
                }
                // Only branch points count against the budget: forced
                // moves (single candidate) don't shrink the explored
                // depth. Total grants stay bounded regardless — a
                // scenario spinning through forced moves forever is
                // handed to the fair fallback at `fair_cap` grants,
                // whose own cap turns livelock into a loud panic.
                if options.len() > 1 {
                    branch_decisions += 1;
                }
                if branch_decisions >= cfg.decision_budget
                    || decisions.len() as u64 >= cfg.fair_cap
                {
                    truncated = true;
                }
                pick
            };
            current = Some(pick);
            st.granted = Some(pick);
            rt.thread_cv.notify_all();
        }
    }
    for h in handles {
        h.join().expect("model thread wrapper is panic-free");
    }
    let mut st = rt.state.lock().unwrap();
    ExecOutcome {
        decisions,
        races: std::mem::take(&mut st.races),
        truncated,
        panic: st.panics.pop(),
    }
}

/// Candidate threads at a decision point, deterministic order.
///
/// * Current thread Ready and not spinning: continuing it is free
///   (options[0]); switching to any other non-spinning Ready thread is a
///   preemption, offered only while budget remains.
/// * Otherwise (current finished or yielded): switching is free and all
///   non-spinning Ready threads are candidates; if *everyone* is
///   spinning, fall back to a single round-robin choice so the execution
///   keeps making progress instead of branching over symmetric spins.
fn compute_options(
    st: &ExecState,
    current: Option<usize>,
    ready: &[usize],
    budget: u32,
) -> Vec<usize> {
    let non_yielded: Vec<usize> = ready
        .iter()
        .copied()
        .filter(|&i| !st.threads[i].yielded)
        .collect();
    if let Some(c) = current {
        if st.threads[c].status == Status::Ready && !st.threads[c].yielded {
            let mut opts = vec![c];
            if budget > 0 {
                opts.extend(non_yielded.iter().copied().filter(|&i| i != c));
            }
            return opts;
        }
    }
    if !non_yielded.is_empty() {
        return non_yielded;
    }
    let start = current.map_or(0, |c| c + 1);
    let n = st.threads.len();
    let rr = (0..n)
        .map(|k| (start + k) % n)
        .find(|i| ready.contains(i))
        .expect("ready is non-empty");
    vec![rr]
}
