//! Model-checkable replicas of crate-internal protocols.
//!
//! The scheduler's executor slots ([`crate::scheduler::executor`]) mix
//! the lock-free publish→echo protocol with OS parking (a condvar) that
//! the virtual scheduler cannot own. These scenario builders replicate
//! the *protocol* — the part every SAFETY comment in `executor.rs` leans
//! on — spin-only, using the same [`crate::exec::sync`] facade types and,
//! crucially, the **same ordering constants** the real executor compiles
//! with: the `cupso_mutate_executor_done` mutation weakens the real
//! `done`-echo store and these scenarios together, so the modelcheck CI
//! job proves the detector catches the weakening.
//!
//! The payload stands in as a `u64` (the real slot carries a `Cmd` /
//! `StepReport`); the race detector only cares that the cells are
//! unsynchronized-or-not, not what they hold.

use super::Scenario;
use crate::exec::sync::{spin_loop, AtomicBool, AtomicU64, Ordering, RacyCell};
use crate::scheduler::executor::DONE_ECHO_ORDERING;
use std::sync::Arc;

/// The executor command slot, shapes and orderings as in
/// `scheduler/executor.rs`: `cmd` written by the producer only while
/// `done == gen`, published by a Release `gen` bump; `report` written by
/// the consumer before the `done` echo and taken by the producer after
/// observing it.
struct ModelSlot {
    gen: AtomicU64,
    done: AtomicU64,
    cmd: RacyCell<Option<u64>>,
    report: RacyCell<Option<u64>>,
    poisoned: AtomicBool,
    shutdown: AtomicBool,
}

// SAFETY: `cmd` and `report` are guarded by the gen/done publish→echo
// protocol (the property the model checker verifies); everything else is
// atomic.
unsafe impl Sync for ModelSlot {}
// SAFETY: all fields are Send.
unsafe impl Send for ModelSlot {}

impl ModelSlot {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            gen: AtomicU64::new(0),
            done: AtomicU64::new(0),
            cmd: RacyCell::new(None),
            report: RacyCell::new(None),
            poisoned: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        })
    }
}

/// Happy path: `rounds` publish→step→echo round trips, then shutdown.
/// Checks echo integrity (every report read back intact) and — under the
/// model — that `cmd`/`report` accesses are fully synchronized.
pub fn executor_slot_scenario(rounds: u64) -> Scenario {
    let slot = ModelSlot::new();
    let mut s = Scenario::new();
    let p = slot.clone();
    s.thread(move || {
        // Producer: StreamExecutors::{submit, wait, take_report}.
        for r in 1..=rounds {
            // SAFETY: replica of submit — done == gen here (round r-1
            // fully echoed), so the consumer is not touching the cell.
            unsafe { *p.cmd.write() = Some(r) };
            p.gen.fetch_add(1, Ordering::Release);
            while p.done.load(Ordering::Acquire) != r {
                spin_loop();
            }
            assert!(
                !p.poisoned.load(Ordering::Acquire),
                "unexpected poison in the happy path"
            );
            // SAFETY: replica of take_report — the echo was observed, so
            // the consumer's report write happened-before this read.
            let got = unsafe { (*p.report.read()).take() };
            assert_eq!(got, Some(r * 2), "round {r}: echo lost or torn");
        }
        p.shutdown.store(true, Ordering::SeqCst);
    });
    let c = slot.clone();
    s.thread(move || {
        // Consumer: executor_loop, minus the condvar parking.
        let mut seen = 0u64;
        loop {
            if c.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let g = c.gen.load(Ordering::Acquire);
            if g == seen {
                spin_loop();
                continue;
            }
            // SAFETY: replica of the executor's cmd read — the slot for
            // `g` was fully published before the Release bump this
            // Acquire load observed.
            let cmd = unsafe { (*c.cmd.read()).expect("a bumped gen has a published cmd") };
            // SAFETY: the producer does not touch `report` until the
            // echo below.
            unsafe { *c.report.write() = Some(cmd * 2) };
            seen = g;
            c.done.store(g, DONE_ECHO_ORDERING);
        }
    });
    let q = slot;
    s.check(move || {
        assert_eq!(
            q.gen.load(Ordering::Relaxed),
            rounds,
            "every round was published"
        );
        assert_eq!(
            q.done.load(Ordering::Relaxed),
            rounds,
            "every round was echoed"
        );
    });
    s
}

/// Poison path: the consumer's command "panics" — it must still echo
/// (or the producer's wait would hang forever), flagging `poisoned`
/// instead of writing a report; the producer must observe the poison and
/// never touch the report cell.
pub fn executor_poison_scenario() -> Scenario {
    let slot = ModelSlot::new();
    let mut s = Scenario::new();
    let p = slot.clone();
    s.thread(move || {
        // SAFETY: done == gen (nothing in flight); consumer not reading.
        unsafe { *p.cmd.write() = Some(7) };
        p.gen.fetch_add(1, Ordering::Release);
        while p.done.load(Ordering::Acquire) != 1 {
            spin_loop();
        }
        assert!(
            p.poisoned.load(Ordering::Acquire),
            "the poisoned round must be observed as poisoned"
        );
        // take_report would panic here; the report cell is never read.
        p.shutdown.store(true, Ordering::SeqCst);
    });
    let c = slot;
    s.thread(move || {
        loop {
            if c.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let g = c.gen.load(Ordering::Acquire);
            if g == 0 {
                spin_loop();
                continue;
            }
            // The command "panicked": no report write, poison instead —
            // but the echo still happens, so wait() cannot hang.
            c.poisoned.store(true, Ordering::Release);
            c.done.store(g, DONE_ECHO_ORDERING);
            // Park until shutdown (the real loop would re-spin).
            while !c.shutdown.load(Ordering::SeqCst) {
                spin_loop();
            }
            return;
        }
    });
    s
}
