//! Deterministic concurrency model checking for the exec primitives.
//!
//! The paper's contribution is a claim about concurrent memory effects —
//! atomic queue updates and a CAS spin lock beating reduction by avoiding
//! synchronization overhead — and this crate encodes that claim in a
//! handful of hand-rolled lock-free protocols (`exec::SpinLock`,
//! `exec::AtomicF64`, `exec::SharedQueue`, the scheduler's executor
//! slots). This module is the first tool in the repo that can *refute*
//! one of those protocols' memory orderings instead of merely failing to
//! observe a bug:
//!
//! * a [`Scenario`] is a set of closures (model threads) over fresh
//!   shared state plus a post-execution invariant check;
//! * under `--cfg cupso_model`, [`Explorer::explore`] runs the scenario
//!   under every schedule of a bounded-exhaustive CHESS-style search
//!   (preemption-bounded DFS at atomic-op granularity) for 2–3 threads,
//!   or under seeded-random schedules beyond that, with a vector-clock
//!   data-race detector watching every [`crate::exec::sync::RacyCell`]
//!   access (see [`runtime`]-module docs for the algorithm);
//! * without the cfg the same tests still compile and run as bounded
//!   real-thread stress executions (no detector, no schedule control),
//!   so `cargo test modelcheck` is meaningful in every build.
//!
//! The detector earns its keep in CI forever via mutation self-tests:
//! weakening `SpinLock`'s unlock store or the executor's completion echo
//! from `Release` to `Relaxed` (`--cfg cupso_mutate_spinlock_release` /
//! `--cfg cupso_mutate_executor_done`) must flip the corresponding
//! modelcheck test from green to red — the CI `modelcheck` job asserts
//! exactly that.

#[cfg(cupso_model)]
pub(crate) mod runtime;

pub mod protocols;

#[cfg(cupso_model)]
use crate::rng::{RngEngine, Xoshiro256pp};

/// One reported data race (deduplicated per location per execution).
#[derive(Debug, Clone)]
pub struct Race {
    /// Human-readable description: threads, access kinds, location.
    pub desc: String,
}

/// A concurrency scenario: model threads over fresh shared state, plus an
/// optional post-execution invariant check (runs after every execution,
/// after all threads joined; a panic fails the exploration).
#[derive(Default)]
pub struct Scenario {
    threads: Vec<Box<dyn FnOnce() + Send>>,
    check: Option<Box<dyn FnOnce()>>,
}

impl Scenario {
    /// Empty scenario.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a model thread.
    pub fn thread<F: FnOnce() + Send + 'static>(&mut self, f: F) -> &mut Self {
        self.threads.push(Box::new(f));
        self
    }

    /// Set the post-execution invariant check.
    pub fn check<F: FnOnce() + 'static>(&mut self, f: F) -> &mut Self {
        self.check = Some(Box::new(f));
        self
    }
}

/// Outcome of an exploration.
#[derive(Debug, Default)]
pub struct Report {
    /// Executions run.
    pub schedules: u64,
    /// Executions that hit the decision budget and finished under the
    /// fair fallback scheduler (explored as a prefix only).
    pub truncated: u64,
    /// DFS exhausted the bounded schedule space within `max_schedules`
    /// (always `false` in random and stress modes).
    pub exhausted: bool,
    /// Data races found (exploration stops at the first racy schedule
    /// unless [`Explorer::continue_past_races`] is set).
    pub races: Vec<Race>,
}

impl Report {
    /// No data race observed in any explored schedule.
    pub fn race_free(&self) -> bool {
        self.races.is_empty()
    }
}

/// Schedule-exploring model checker (see module docs).
///
/// Defaults: preemption bound 2, decision budget 400 per execution, at
/// most 20 000 schedules, DFS for ≤ 3 threads / seeded-random beyond,
/// 64 stress executions in non-model builds.
#[allow(dead_code)] // each build shape reads its own subset of the knobs
pub struct Explorer {
    preemptions: u32,
    decision_budget: u64,
    fair_cap: u64,
    max_schedules: u64,
    seed: Option<u64>,
    stress_iters: u64,
    stop_on_race: bool,
}

impl Default for Explorer {
    fn default() -> Self {
        Self::new()
    }
}

impl Explorer {
    /// Explorer with the default bounds.
    pub fn new() -> Self {
        Self {
            preemptions: 2,
            decision_budget: 400,
            fair_cap: 1_000_000,
            max_schedules: 20_000,
            seed: None,
            stress_iters: 64,
            stop_on_race: true,
        }
    }

    /// CHESS-style context bound: preemptive switches per execution.
    pub fn preemptions(mut self, p: u32) -> Self {
        self.preemptions = p;
        self
    }

    /// Branch points (scheduling decisions with ≥ 2 candidates) explored
    /// per execution before the fair fallback finishes it
    /// deterministically; forced moves don't count against it.
    pub fn decision_budget(mut self, d: u64) -> Self {
        self.decision_budget = d;
        self
    }

    /// Upper bound on executions.
    pub fn max_schedules(mut self, m: u64) -> Self {
        self.max_schedules = m;
        self
    }

    /// Force seeded-random scheduling (also the default above 3 threads).
    pub fn random_seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Executions per scenario in non-model (stress) builds.
    pub fn stress_iters(mut self, n: u64) -> Self {
        self.stress_iters = n;
        self
    }

    /// Keep exploring after a race is found (for scenarios that assert
    /// counter invariants while *expecting* unsynchronized cells, e.g.
    /// queue pushes racing a reset). At most 16 races are recorded.
    pub fn continue_past_races(mut self) -> Self {
        self.stop_on_race = false;
        self
    }

    /// Explore the scenario produced by `factory` (called once per
    /// execution — shared state must be rebuilt fresh each time).
    #[cfg(cupso_model)]
    pub fn explore<F: FnMut() -> Scenario>(&self, mut factory: F) -> Report {
        use runtime::{run_schedule, Mode, ScheduleCfg};
        let cfg = ScheduleCfg {
            preemptions: self.preemptions,
            decision_budget: self.decision_budget,
            fair_cap: self.fair_cap,
        };
        let mut report = Report::default();
        let mut scenario = factory();
        let randomized = self.seed.is_some() || scenario.threads.len() > 3;
        if randomized {
            let mut rng = Xoshiro256pp::seeded(self.seed.unwrap_or(0xC0FF_EE00));
            loop {
                let Scenario { threads, check } = scenario;
                let mut pick = |n: usize| (rng.next_u64() % n as u64) as usize;
                let outcome = run_schedule(threads, &cfg, Mode::Random { rng: &mut pick });
                if self.record(&mut report, outcome, check) {
                    return report;
                }
                if report.schedules >= self.max_schedules {
                    return report;
                }
                scenario = factory();
            }
        }
        // Bounded-exhaustive DFS over (free-switch × preemption) choices.
        let mut forced: Vec<usize> = Vec::new();
        loop {
            let Scenario { threads, check } = scenario;
            let outcome = run_schedule(threads, &cfg, Mode::Dfs { forced: &forced });
            let mut decisions = outcome.decisions.clone();
            if self.record(&mut report, outcome, check) {
                return report;
            }
            if report.schedules >= self.max_schedules {
                return report;
            }
            // Backtrack to the deepest decision with an untried option.
            loop {
                match decisions.last_mut() {
                    None => {
                        report.exhausted = true;
                        return report;
                    }
                    Some(d) if d.taken + 1 < d.options => {
                        d.taken += 1;
                        break;
                    }
                    _ => {
                        decisions.pop();
                    }
                }
            }
            forced = decisions.iter().map(|d| d.taken).collect();
            scenario = factory();
        }
    }

    /// Fold one execution into the report; true = stop exploring.
    #[cfg(cupso_model)]
    fn record(
        &self,
        report: &mut Report,
        outcome: runtime::ExecOutcome,
        check: Option<Box<dyn FnOnce()>>,
    ) -> bool {
        report.schedules += 1;
        if outcome.truncated {
            report.truncated += 1;
        }
        if let Some(p) = outcome.panic {
            std::panic::resume_unwind(p);
        }
        if let Some(check) = check {
            check();
        }
        if !outcome.races.is_empty() {
            let room = 16usize.saturating_sub(report.races.len());
            report.races.extend(outcome.races.into_iter().take(room));
            if self.stop_on_race {
                return true;
            }
        }
        false
    }

    /// Non-model fallback: bounded real-thread stress executions (no
    /// schedule control, no race detector — the `--cfg cupso_model` CI
    /// job runs the real exploration).
    #[cfg(not(cupso_model))]
    pub fn explore<F: FnMut() -> Scenario>(&self, mut factory: F) -> Report {
        let mut report = Report::default();
        for _ in 0..self.stress_iters {
            let Scenario { threads, check } = factory();
            let handles: Vec<_> = threads.into_iter().map(std::thread::spawn).collect();
            for h in handles {
                if let Err(p) = h.join() {
                    std::panic::resume_unwind(p);
                }
            }
            if let Some(check) = check {
                check();
            }
            report.schedules += 1;
        }
        report
    }
}
