//! Random-number substrate.
//!
//! The paper (§5.4) uses cuRAND's default engine — **Philox4x32-10**, a
//! counter-based generator — and compares it against a "custom-made"
//! generator, reporting cuRAND ≈1.1× faster in the PPSO hot loop. We
//! reproduce both sides:
//!
//! * [`Philox4x32`] — bit-exact Philox4x32-10 (Salmon et al., SC'11), the
//!   cuRAND analog. Counter-based: `(key, counter) -> 4×u32`, so a particle
//!   can derive its stream from `(seed, particle_id, iteration)` without
//!   shared state — exactly how cuRAND seeds per-thread states.
//! * [`Xoshiro256pp`] — xoshiro256++, the "custom RNG" of the §5.4 ablation.
//! * [`SplitMix64`] — seeding/stream-splitting utility (also used by the
//!   property-test support).
//!
//! All generators implement [`RngEngine`]; `benches/ablation_rng.rs` swaps
//! them inside the same engine hot loop to re-measure the 1.1× claim.

mod philox;
mod splitmix;
mod xoshiro;

pub use philox::{Philox4x32, PhiloxStream};
pub use splitmix::SplitMix64;
pub use xoshiro::Xoshiro256pp;

/// Minimal uniform-random interface used by every PSO engine.
///
/// Object-safe so engines can hold `Box<dyn RngEngine>` when the generator
/// is chosen at runtime (CLI `--rng`), while the hot loops are generic and
/// monomorphised.
pub trait RngEngine: Send {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)` with 53-bit resolution.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // 53 high bits / 2^53 — the standard unbiased dyadic construction.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fork an independent stream for worker `id`.
    ///
    /// Streams must be statistically independent for distinct ids; every
    /// implementation derives the child from `(state, id)` through
    /// SplitMix64 or a Philox key change.
    fn fork(&self, id: u64) -> Box<dyn RngEngine>;
}

/// Which generator to use — runtime-selectable (CLI `--rng`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RngKind {
    /// Philox4x32-10, the cuRAND-equivalent counter-based engine (default).
    Philox,
    /// xoshiro256++, the "custom RNG" of the paper's §5.4 ablation.
    Xoshiro,
}

impl RngKind {
    /// Instantiate a boxed engine seeded with `seed`.
    pub fn build(self, seed: u64) -> Box<dyn RngEngine> {
        match self {
            RngKind::Philox => Box::new(Philox4x32::seeded(seed)),
            RngKind::Xoshiro => Box::new(Xoshiro256pp::seeded(seed)),
        }
    }

    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "philox" | "curand" => Some(RngKind::Philox),
            "xoshiro" | "custom" => Some(RngKind::Xoshiro),
            _ => None,
        }
    }
}

impl std::fmt::Display for RngKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RngKind::Philox => write!(f, "philox"),
            RngKind::Xoshiro => write!(f, "xoshiro"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn basic_uniformity<R: RngEngine>(mut r: R) {
        const N: usize = 20_000;
        let mut sum = 0.0;
        let mut buckets = [0usize; 10];
        for _ in 0..N {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x), "out of range: {x}");
            sum += x;
            buckets[(x * 10.0) as usize] += 1;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean off: {mean}");
        for (i, &b) in buckets.iter().enumerate() {
            let frac = b as f64 / N as f64;
            assert!(
                (frac - 0.1).abs() < 0.02,
                "bucket {i} skewed: {frac}"
            );
        }
    }

    #[test]
    fn philox_uniform() {
        basic_uniformity(Philox4x32::seeded(7));
    }

    #[test]
    fn xoshiro_uniform() {
        basic_uniformity(Xoshiro256pp::seeded(7));
    }

    #[test]
    fn uniform_range_respects_bounds() {
        let mut r = Philox4x32::seeded(3);
        for _ in 0..1000 {
            let x = r.uniform(-100.0, 100.0);
            assert!((-100.0..100.0).contains(&x));
        }
    }

    #[test]
    fn forks_are_decorrelated() {
        let base = Philox4x32::seeded(11);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let mut same = 0;
        for _ in 0..1000 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        assert_eq!(same, 0, "forked streams collide");
    }

    #[test]
    fn kind_parse_roundtrip() {
        assert_eq!(RngKind::parse("philox"), Some(RngKind::Philox));
        assert_eq!(RngKind::parse("curand"), Some(RngKind::Philox));
        assert_eq!(RngKind::parse("XOSHIRO"), Some(RngKind::Xoshiro));
        assert_eq!(RngKind::parse("custom"), Some(RngKind::Xoshiro));
        assert_eq!(RngKind::parse("mt19937"), None);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Philox4x32::seeded(99);
        let mut b = Philox4x32::seeded(99);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
