//! SplitMix64 — Steele, Lea & Flood (OOPSLA'14). Used for seeding the other
//! generators and for cheap stream splitting; passes BigCrush on its own.

use super::RngEngine;

/// SplitMix64 state: a single 64-bit counter advanced by the golden gamma.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Golden-ratio increment.
    pub const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

    /// New generator from a raw seed.
    #[inline]
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 bits (the canonical finalizer).
    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(Self::GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// One-shot mix — hash `x` without constructing a generator. Used to
    /// derive decorrelated child seeds: `mix(seed ^ mix(id))`.
    #[inline]
    pub fn mix(x: u64) -> u64 {
        let mut z = x.wrapping_add(Self::GAMMA);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl RngEngine for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.next()
    }

    fn fork(&self, id: u64) -> Box<dyn RngEngine> {
        Box::new(SplitMix64::new(SplitMix64::mix(self.state ^ SplitMix64::mix(id))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_first_outputs() {
        // Reference vector: seed 0 → first output of SplitMix64.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next(), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn mix_is_stateless_hash() {
        assert_eq!(SplitMix64::mix(42), SplitMix64::mix(42));
        assert_ne!(SplitMix64::mix(42), SplitMix64::mix(43));
    }

    #[test]
    fn sequence_has_no_short_cycle() {
        let mut r = SplitMix64::new(1234);
        let first = r.next();
        for _ in 0..10_000 {
            assert_ne!(r.next(), first);
        }
    }
}
