//! Philox4x32-10 — Salmon, Moraes, Dror & Shaw, "Parallel Random Numbers:
//! As Easy as 1, 2, 3" (SC'11). This is cuRAND's default engine
//! (`curand_uniform_double()` in the paper §5.4) and the generator our
//! JAX plane conceptually mirrors (threefry is its sibling).
//!
//! Counter-based: `bijection(key, counter) -> 4×u32`. Perfect for the PSO
//! use-case the paper describes — each CUDA thread (here: each particle /
//! worker) derives an independent stream purely from its id, with no shared
//! mutable state and no warm-up.

use super::{RngEngine, SplitMix64};

const PHILOX_M4X32_A: u32 = 0xD251_1F53;
const PHILOX_M4X32_B: u32 = 0xCD9E_8D57;
const PHILOX_W32_A: u32 = 0x9E37_79B9;
const PHILOX_W32_B: u32 = 0xBB67_AE85;
const ROUNDS: usize = 10;

/// One Philox round: multiply-hi/lo mixing of the 4-lane counter.
#[inline(always)]
fn round(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
    let p0 = (ctr[0] as u64).wrapping_mul(PHILOX_M4X32_A as u64);
    let p1 = (ctr[2] as u64).wrapping_mul(PHILOX_M4X32_B as u64);
    let (hi0, lo0) = ((p0 >> 32) as u32, p0 as u32);
    let (hi1, lo1) = ((p1 >> 32) as u32, p1 as u32);
    [hi1 ^ ctr[1] ^ key[0], lo1, hi0 ^ ctr[3] ^ key[1], lo0]
}

/// The core keyed bijection: 10 rounds with bumped keys.
#[inline]
pub fn philox4x32_10(mut ctr: [u32; 4], mut key: [u32; 2]) -> [u32; 4] {
    for r in 0..ROUNDS {
        if r > 0 {
            key[0] = key[0].wrapping_add(PHILOX_W32_A);
            key[1] = key[1].wrapping_add(PHILOX_W32_B);
        }
        ctr = round(ctr, key);
    }
    ctr
}

/// Sequential Philox4x32-10 generator: a key plus a 128-bit counter that
/// increments per block of 4 outputs. Equivalent to cuRAND's
/// `curandStatePhilox4_32_10_t` stepping.
#[derive(Debug, Clone)]
pub struct Philox4x32 {
    key: [u32; 2],
    ctr: [u32; 4],
    /// Buffered outputs from the last block (we hand out 2×u64 per block).
    buf: [u32; 4],
    /// Next u32 pair to consume from `buf` (0, 2, or 4=refill).
    cursor: usize,
}

impl Philox4x32 {
    /// Construct from an explicit 64-bit key (cuRAND "seed").
    pub fn new(key: u64) -> Self {
        Self {
            key: [key as u32, (key >> 32) as u32],
            ctr: [0; 4],
            buf: [0; 4],
            cursor: 4,
        }
    }

    /// Seed through SplitMix64 so small integer seeds spread over the key
    /// space (mirrors cuRAND's seed scrambling).
    pub fn seeded(seed: u64) -> Self {
        Self::new(SplitMix64::mix(seed))
    }

    /// Jump the 128-bit counter by one block.
    #[inline]
    fn bump(&mut self) {
        for lane in &mut self.ctr {
            let (v, carry) = lane.overflowing_add(1);
            *lane = v;
            if !carry {
                break;
            }
        }
    }

    #[inline]
    fn refill(&mut self) {
        self.buf = philox4x32_10(self.ctr, self.key);
        self.bump();
        self.cursor = 0;
    }
}

impl RngEngine for Philox4x32 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        if self.cursor >= 4 {
            self.refill();
        }
        let lo = self.buf[self.cursor] as u64;
        let hi = self.buf[self.cursor + 1] as u64;
        self.cursor += 2;
        (hi << 32) | lo
    }

    fn fork(&self, id: u64) -> Box<dyn RngEngine> {
        // A forked stream changes the *key*, which Philox guarantees yields
        // an independent permutation of the counter space.
        let base = ((self.key[1] as u64) << 32) | self.key[0] as u64;
        Box::new(Philox4x32::new(SplitMix64::mix(base ^ SplitMix64::mix(id))))
    }
}

/// Stateless counter-based access — the exact pattern the paper's GPU code
/// uses (`curand_init(seed, tid, offset, &state)`): draw `k`-th uniform of
/// particle `pid` at iteration `iter` with no shared state. This is also
/// bit-for-bit the scheme `python/compile/model.py` mirrors with threefry
/// (fold key by iteration, vectorize over particles).
#[derive(Debug, Clone, Copy)]
pub struct PhiloxStream {
    key: [u32; 2],
}

impl PhiloxStream {
    /// A stream namespace from a global seed.
    pub fn new(seed: u64) -> Self {
        let k = SplitMix64::mix(seed);
        Self {
            key: [k as u32, (k >> 32) as u32],
        }
    }

    /// The 4 uniform doubles for `(particle, iteration, slot)`.
    ///
    /// `slot` selects among the independent draws one PSO update needs
    /// (r1 and r2 per dimension → slot = dim index).
    #[inline]
    pub fn uniform4(&self, particle: u64, iteration: u64, slot: u32) -> [f64; 4] {
        let ctr = [
            particle as u32,
            (particle >> 32) as u32,
            iteration as u32,
            slot ^ ((iteration >> 32) as u32),
        ];
        let o = philox4x32_10(ctr, self.key);
        // Pair u32s into 53-bit doubles like next_f64 does.
        let d0 = ((((o[1] as u64) << 32) | o[0] as u64) >> 11) as f64
            * (1.0 / (1u64 << 53) as f64);
        let d1 = ((((o[3] as u64) << 32) | o[2] as u64) >> 11) as f64
            * (1.0 / (1u64 << 53) as f64);
        // Also expose the two single-u32 resolutions for f32-grade use.
        let s0 = o[0] as f64 * (1.0 / 4294967296.0);
        let s1 = o[2] as f64 * (1.0 / 4294967296.0);
        [d0, d1, s0, s1]
    }

    /// The `(r1, r2)` pair Eq. 1 needs for `(particle, iteration, dim)`.
    #[inline]
    pub fn r1r2(&self, particle: u64, iteration: u64, dim: u32) -> (f64, f64) {
        let u = self.uniform4(particle, iteration, dim);
        (u[0], u[1])
    }

    /// Four consecutive particles' `(r1, r2)` pairs in one call —
    /// **bit-identical** to four [`Self::r1r2`] calls (same per-lane
    /// counters and key), but laid out so LLVM vectorizes the ten Philox
    /// rounds across lanes (~3.7× on this host; EXPERIMENTS.md §Perf).
    /// Used by the engines' dimension-major row loop.
    #[inline]
    pub fn r1r2_x4(&self, particle0: u64, iteration: u64, dim: u32) -> [(f64, f64); 4] {
        let mut ctr = [[0u32; 4]; 4];
        for (l, lane) in ctr.iter_mut().enumerate() {
            let p = particle0 + l as u64;
            *lane = [
                p as u32,
                (p >> 32) as u32,
                iteration as u32,
                dim ^ ((iteration >> 32) as u32),
            ];
        }
        // Transpose to word-major lanes for the batched rounds.
        let mut c = [[0u32; 4]; 4];
        for w in 0..4 {
            for l in 0..4 {
                c[w][l] = ctr[l][w];
            }
        }
        let mut key = self.key;
        for r in 0..ROUNDS {
            if r > 0 {
                key[0] = key[0].wrapping_add(PHILOX_W32_A);
                key[1] = key[1].wrapping_add(PHILOX_W32_B);
            }
            // One round across all four lanes (vectorizable).
            for l in 0..4 {
                let p0 = (c[0][l] as u64).wrapping_mul(PHILOX_M4X32_A as u64);
                let p1 = (c[2][l] as u64).wrapping_mul(PHILOX_M4X32_B as u64);
                let (hi0, lo0) = ((p0 >> 32) as u32, p0 as u32);
                let (hi1, lo1) = ((p1 >> 32) as u32, p1 as u32);
                let n0 = hi1 ^ c[1][l] ^ key[0];
                let n2 = hi0 ^ c[3][l] ^ key[1];
                c[0][l] = n0;
                c[1][l] = lo1;
                c[2][l] = n2;
                c[3][l] = lo0;
            }
        }
        let scale = 1.0 / (1u64 << 53) as f64;
        let mut out = [(0.0, 0.0); 4];
        for (l, slot) in out.iter_mut().enumerate() {
            let d0 = ((((c[1][l] as u64) << 32) | c[0][l] as u64) >> 11) as f64 * scale;
            let d1 = ((((c[3][l] as u64) << 32) | c[2][l] as u64) >> 11) as f64 * scale;
            *slot = (d0, d1);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::RngEngine;

    /// Known-answer test from the Random123 reference implementation.
    /// philox4x32-10 with ctr = {0,0,0,0}, key = {0,0}.
    #[test]
    fn kat_zero() {
        let out = philox4x32_10([0, 0, 0, 0], [0, 0]);
        assert_eq!(out, [0x6627_E8D5, 0xE169_C58D, 0xBC57_AC4C, 0x9B00_DBD8]);
    }

    /// KAT: ctr = key = all-ones (Random123 test vectors).
    #[test]
    fn kat_ones() {
        let out = philox4x32_10(
            [0xFFFF_FFFF; 4],
            [0xFFFF_FFFF; 2],
        );
        assert_eq!(out, [0x408F_276D, 0x41C8_3B0E, 0xA20B_C7C6, 0x6D54_51FD]);
    }

    /// KAT: the pi-digits vector from Random123.
    #[test]
    fn kat_pi() {
        let out = philox4x32_10(
            [0x243F_6A88, 0x85A3_08D3, 0x1319_8A2E, 0x0370_7344],
            [0xA409_3822, 0x299F_31D0],
        );
        assert_eq!(out, [0xD16C_FE09, 0x94FD_CCEB, 0x5001_E420, 0x2412_6EA1]);
    }

    #[test]
    fn stream_is_reproducible_and_slot_separated() {
        let s = PhiloxStream::new(2022);
        assert_eq!(s.r1r2(5, 100, 0), s.r1r2(5, 100, 0));
        assert_ne!(s.r1r2(5, 100, 0), s.r1r2(5, 100, 1));
        assert_ne!(s.r1r2(5, 100, 0), s.r1r2(6, 100, 0));
        assert_ne!(s.r1r2(5, 100, 0), s.r1r2(5, 101, 0));
    }

    #[test]
    fn stream_uniform_stats() {
        let s = PhiloxStream::new(7);
        let mut sum = 0.0;
        let n = 10_000u64;
        for p in 0..n {
            let (a, b) = s.r1r2(p, 0, 0);
            assert!((0.0..1.0).contains(&a) && (0.0..1.0).contains(&b));
            sum += a + b;
        }
        let mean = sum / (2 * n) as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn r1r2_x4_bit_identical_to_scalar() {
        let s = PhiloxStream::new(77);
        for base in [0u64, 5, 1000, u32::MAX as u64] {
            for iter in [0u64, 3, 1 << 40] {
                for dim in [0u32, 1, 119] {
                    let batch = s.r1r2_x4(base, iter, dim);
                    for l in 0..4 {
                        assert_eq!(
                            batch[l],
                            s.r1r2(base + l as u64, iter, dim),
                            "lane {l} base={base} iter={iter} dim={dim}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sequential_counter_advances() {
        let mut g = Philox4x32::new(1);
        let a = g.next_u64();
        let b = g.next_u64();
        let c = g.next_u64(); // crosses block boundary
        assert_ne!(a, b);
        assert_ne!(b, c);
    }
}
