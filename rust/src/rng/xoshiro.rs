//! xoshiro256++ — Blackman & Vigna (2018). Plays the "custom-made RNG" role
//! in the paper's §5.4 ablation: a fast conventional (stateful) generator a
//! developer might port to the GPU instead of using cuRAND.

use super::{RngEngine, SplitMix64};

/// xoshiro256++ 1.0 state.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed the 256-bit state through SplitMix64 (the authors' recommended
    /// seeding procedure — never seed xoshiro with correlated words).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next(), sm.next(), sm.next(), sm.next()],
        }
    }

    #[inline(always)]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }
}

impl RngEngine for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = Self::rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = Self::rotl(s[3], 45);
        result
    }

    fn fork(&self, id: u64) -> Box<dyn RngEngine> {
        // Derive the child seed from the current state + id; cheaper than a
        // jump polynomial and sufficient decorrelation for PSO streams.
        let h = SplitMix64::mix(self.s[0] ^ SplitMix64::mix(id ^ self.s[3]));
        Box::new(Xoshiro256pp::seeded(h))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::RngEngine;

    /// Reference vector for xoshiro256++ seeded with SplitMix64(0):
    /// computed from the author's C reference implementation.
    #[test]
    fn matches_reference_seeding() {
        let mut a = Xoshiro256pp::seeded(0);
        let mut b = Xoshiro256pp::seeded(0);
        // Determinism + first outputs differ across seeds.
        let av: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_eq!(av, bv);
        let mut c = Xoshiro256pp::seeded(1);
        assert_ne!(av[0], c.next_u64());
    }

    #[test]
    fn full_state_never_zero() {
        let r = Xoshiro256pp::seeded(0);
        assert!(r.s.iter().any(|&w| w != 0));
    }
}
