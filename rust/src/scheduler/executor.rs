//! Persistent per-stream executor threads — the scheduler's answer to its
//! own "launch overhead".
//!
//! Before this module the scheduler stepped a concurrent round by
//! spawning and joining S−1 scoped OS threads *per scheduling round*: at
//! `batch_steps = 1` a 100k-iteration batch paid ~100k thread spawns per
//! stream — exactly the dispatch/join fixed cost the paper measures one
//! level down in [`crate::exec::GridPool::launch`]. An executor makes the
//! round a **publish + wake** instead: one long-lived thread per extra
//! stream parks on a command slot, the scheduler writes `(run, k)` into
//! the slot and bumps a generation counter, and the executor echoes the
//! generation when the batch of steps is done.
//!
//! ## Handoff protocol (single producer, single consumer per slot)
//!
//! This reuses the spin-then-park discipline of [`crate::exec::pool`],
//! simplified because each slot has exactly one producer (the scheduling
//! thread) and one consumer (its executor):
//!
//! * the producer writes the command slot only while `done == gen` (the
//!   previous round fully echoed), then bumps `gen` (Release) and
//!   notifies the condvar;
//! * the executor spins briefly for a new generation, parks on the
//!   condvar after its spin budget, and on wake re-loads `gen` (Acquire)
//!   — ordered after the Release bump, so the slot write is visible;
//! * the executor runs `run.step_many(k)`, moves the [`StepReport`] into
//!   its report cell, and stores `done = gen` (Release); the producer
//!   spin-waits for the echo (Acquire) before touching the run, the
//!   report, or the slot again.
//!
//! The `*mut dyn Run` in the slot is lifetime-erased exactly like the
//! pool's kernel pointer: it is only ever dereferenced between publish
//! and echo, and [`StreamExecutors::wait`] must be called for every
//! submitted slot before the round's borrows end (the scheduler's
//! `step_round` upholds this; `Drop` shuts the threads down without
//! touching any command).
//!
//! Steady-state cost per round and slot: one slot write, one atomic bump,
//! one uncontended mutex lock + notify, one spin-wait — and **zero heap
//! allocations** (`rust/tests/zero_alloc.rs`).

use crate::engine::{Run, StepReport};
use crate::exec::sync::{self, AtomicBool, AtomicU64, Ordering, RacyCell};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Ordering of the executor's completion echo (`done.store(gen)`). The
/// `Release` is what orders the report write before the producer's
/// Acquire spin — `wait` then `take_report` lean on exactly this edge.
/// The `cupso_mutate_executor_done` cfg weakens it to `Relaxed` so the
/// modelcheck CI job can prove the race detector refutes the weakened
/// protocol (the replica in [`crate::modelcheck::protocols`] shares this
/// constant, so the mutation hits the real executor and the model
/// scenario together).
#[cfg(not(cupso_mutate_executor_done))]
pub(crate) const DONE_ECHO_ORDERING: Ordering = Ordering::Release;
#[cfg(cupso_mutate_executor_done)]
pub(crate) const DONE_ECHO_ORDERING: Ordering = Ordering::Relaxed;

/// Spin budget before parking when cores are plentiful (matches the
/// pool's discipline). Collapses under Miri, where spinning is
/// interpreted instruction-by-instruction.
const SPIN_ROUNDS_PARALLEL: u32 = if cfg!(miri) { 4 } else { 20_000 };
/// Effectively "yield immediately" when the machine is oversubscribed.
const SPIN_ROUNDS_OVERSUB: u32 = 16;

/// Pick the executor spin budget: spinning only pays when the pool
/// workers, the helping launchers and the executors all fit on distinct
/// cores.
pub(crate) fn spin_budget(total_threads: usize) -> u32 {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= total_threads {
        SPIN_ROUNDS_PARALLEL
    } else {
        SPIN_ROUNDS_OVERSUB
    }
}

/// Type-erased stepping command; the raw run pointer is valid exactly
/// while its round is in flight (publish → echo).
#[derive(Clone, Copy)]
struct Cmd {
    run: *mut (dyn Run + 'static),
    k: u64,
}

// SAFETY: the pointee is only dereferenced inside the publish→echo window
// (module docs), during which the producer relinquishes the borrow.
unsafe impl Send for Cmd {}

struct Slot {
    /// Command generation: bumped (Release) after the slot is written.
    gen: AtomicU64,
    /// Completion echo: the executor stores the finished generation
    /// (Release) after moving the report out.
    done: AtomicU64,
    /// Written by the producer only while `done == gen`.
    cmd: RacyCell<Option<Cmd>>,
    /// The stepped report, written by the executor before the echo and
    /// taken by the producer after it.
    report: RacyCell<Option<StepReport>>,
    /// Set when a command panicked: the echo still arrives (so `wait`
    /// cannot hang), and `take_report` re-raises on the scheduling
    /// thread — matching the legacy scoped-thread `join().expect(…)`
    /// behavior.
    poisoned: AtomicBool,
    shutdown: AtomicBool,
    idle: Mutex<()>,
    cv: Condvar,
    spin_rounds: u32,
}

// SAFETY: `cmd` and `report` are guarded by the gen/done protocol in the
// module docs; everything else is atomic or a sync primitive.
unsafe impl Send for Slot {}
unsafe impl Sync for Slot {}

/// A fixed set of persistent executor threads, one command slot each.
pub(crate) struct StreamExecutors {
    slots: Vec<Arc<Slot>>,
    handles: Vec<JoinHandle<()>>,
}

impl StreamExecutors {
    /// Spawn `count` executors (the scheduler sizes this to the extra
    /// concurrent jobs a round can hold: `min(streams, jobs) - 1`).
    pub fn new(count: usize, spin_rounds: u32) -> Self {
        let slots: Vec<Arc<Slot>> = (0..count)
            .map(|_| {
                Arc::new(Slot {
                    gen: AtomicU64::new(0),
                    done: AtomicU64::new(0),
                    cmd: RacyCell::new(None),
                    report: RacyCell::new(None),
                    poisoned: AtomicBool::new(false),
                    shutdown: AtomicBool::new(false),
                    idle: Mutex::new(()),
                    cv: Condvar::new(),
                    spin_rounds,
                })
            })
            .collect();
        let handles = slots
            .iter()
            .enumerate()
            .map(|(e, slot)| {
                let slot = slot.clone();
                std::thread::Builder::new()
                    .name(format!("cupso-exec-{e}"))
                    .spawn(move || executor_loop(&slot))
                    .expect("spawn stream executor")
            })
            .collect();
        Self { slots, handles }
    }

    /// How many executor threads (command slots) this set holds.
    pub fn count(&self) -> usize {
        self.slots.len()
    }

    /// Publish `(run, k)` to executor `e` and wake it. The executor will
    /// run `run.step_many(k)` and park the report for [`take_report`].
    ///
    /// # Safety
    /// The caller must call [`wait`](Self::wait)`(e)` before `run`'s
    /// borrow ends or the run is touched again, and must not submit to
    /// `e` again before that wait. One round must submit each run to at
    /// most one executor.
    pub unsafe fn submit(&self, e: usize, run: &mut (dyn Run + '_), k: u64) {
        let slot = &*self.slots[e];
        debug_assert_eq!(
            slot.done.load(Ordering::SeqCst),
            slot.gen.load(Ordering::SeqCst),
            "submit while a command is still in flight"
        );
        let ptr: *mut (dyn Run + '_) = run;
        // SAFETY: erasing the run's borrow lifetime is sound because
        // wait(e) happens before the borrow ends (the safety contract
        // above), and the executor only dereferences inside that window.
        let run: *mut (dyn Run + 'static) = unsafe {
            std::mem::transmute::<*mut (dyn Run + '_), *mut (dyn Run + 'static)>(ptr)
        };
        // SAFETY: slot write per the handoff protocol — `done == gen`
        // (asserted above), so the executor is not reading the cell.
        unsafe { *slot.cmd.write() = Some(Cmd { run, k }) };
        slot.gen.fetch_add(1, Ordering::Release);
        let _idle = slot.idle.lock().unwrap();
        slot.cv.notify_one();
    }

    /// Block until executor `e` echoed its latest submitted command.
    pub fn wait(&self, e: usize) {
        let slot = &*self.slots[e];
        let target = slot.gen.load(Ordering::Relaxed);
        let mut spins = 0u32;
        while slot.done.load(Ordering::Acquire) != target {
            spins += 1;
            if spins < slot.spin_rounds {
                sync::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }

    /// Move executor `e`'s report out (valid after [`wait`](Self::wait)).
    /// Panics if the command panicked on the executor thread, exactly as
    /// the legacy scoped-thread join did.
    pub fn take_report(&self, e: usize) -> StepReport {
        let slot = &*self.slots[e];
        debug_assert_eq!(
            slot.done.load(Ordering::SeqCst),
            slot.gen.load(Ordering::SeqCst)
        );
        if slot.poisoned.load(Ordering::Acquire) {
            panic!("stepping executor panicked");
        }
        // SAFETY: the echo ordered the executor's write before this read,
        // and the executor will not touch the cell again until the next
        // submit.
        unsafe { (*slot.report.read()).take() }.expect("executor echoed without a report")
    }
}

impl Drop for StreamExecutors {
    fn drop(&mut self) {
        for slot in &self.slots {
            slot.shutdown.store(true, Ordering::SeqCst);
            let _idle = slot.idle.lock().unwrap();
            slot.cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn executor_loop(slot: &Slot) {
    let mut seen = 0u64;
    loop {
        // Spin for a new generation; park after the spin budget.
        let mut spins = 0u32;
        loop {
            if slot.shutdown.load(Ordering::SeqCst) {
                return;
            }
            if slot.gen.load(Ordering::Acquire) != seen {
                break;
            }
            spins += 1;
            if spins >= slot.spin_rounds {
                let mut idle = slot.idle.lock().unwrap();
                while !slot.shutdown.load(Ordering::SeqCst)
                    && slot.gen.load(Ordering::Acquire) == seen
                {
                    idle = slot.cv.wait(idle).unwrap();
                }
                break;
            }
            sync::spin_loop();
        }
        if slot.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let g = slot.gen.load(Ordering::Acquire);
        // SAFETY: the slot for `g` was fully published before the Release
        // bump this Acquire load observed, and the producer cannot
        // rewrite it until we echo `done = g`.
        if let Some(cmd) = unsafe { *slot.cmd.read() } {
            // A panicking step must still echo, or the producer's `wait`
            // would spin forever; the poison flag re-raises the panic on
            // the scheduling thread at `take_report`.
            let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // SAFETY: the producer holds the run exclusively for us
                // until the echo (the submit safety contract).
                let run = unsafe { &mut *cmd.run };
                run.step_many(cmd.k)
            }));
            match stepped {
                // SAFETY: the producer does not touch `report` until it
                // observes the echo below.
                Ok(report) => unsafe { *slot.report.write() = Some(report) },
                Err(_) => slot.poisoned.store(true, Ordering::Release),
            }
        }
        seen = g;
        slot.done.store(g, DONE_ECHO_ORDERING);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::engine::{self, Engine, ParallelSettings};
    use crate::fitness::{Cubic, Objective};
    use crate::pso::PsoParams;

    #[test]
    fn executors_step_runs_identically_to_inline_stepping() {
        let params = PsoParams::paper_1d(128, 24);
        let settings = ParallelSettings::with_workers(2);
        let mut reference = engine::build_with(EngineKind::Queue, settings.clone()).unwrap();
        let mut r = reference.prepare(&params, &Cubic, Objective::Maximize, 3);
        while !r.step_many(4).done {}
        let expect = r.finish();

        let mut e = engine::build_with(EngineKind::Queue, settings).unwrap();
        let mut run = e.prepare(&params, &Cubic, Objective::Maximize, 3);
        let execs = StreamExecutors::new(1, spin_budget(8));
        loop {
            // SAFETY: wait(0) below precedes every further use of `run`.
            unsafe { execs.submit(0, &mut *run, 4) };
            execs.wait(0);
            if execs.take_report(0).done {
                break;
            }
        }
        let out = run.finish();
        assert_eq!(out.gbest_fit, expect.gbest_fit);
        assert_eq!(out.history, expect.history);
        assert_eq!(out.iters, expect.iters);
    }

    #[test]
    fn executors_shut_down_cleanly_when_idle_or_mid_park() {
        // Dropping without ever submitting must join promptly (threads are
        // parked on the condvar by then).
        let execs = StreamExecutors::new(3, 4);
        std::thread::sleep(std::time::Duration::from_millis(10));
        drop(execs);
    }
}
