//! Multi-job scheduler — many concurrent PSO jobs multiplexed over one
//! shared [`GridPool`].
//!
//! The step-wise engine core ([`crate::engine::Run`]) makes a run a
//! resumable object: all buffers live in the `Run`, a `step()` advances
//! one iteration, and nothing about the trajectory depends on *when* the
//! step executes. [`JobScheduler`] exploits exactly that: it prepares one
//! `Run` per [`JobSpec`], then interleaves single steps over the shared
//! worker pool under a [`SchedPolicy`] until every job hits a
//! [`TerminationCriteria`] bound or exhausts its iteration budget.
//!
//! **Concurrent streams.** When the shared pool is built with `S > 1`
//! stream groups ([`crate::exec::GridPool::with_streams`]), the scheduler
//! runs in concurrent mode: each job is pinned to pool stream
//! `job_index % S` at prepare time, and every scheduling round picks up
//! to `S` live jobs — under the same policy, no two sharing a stream —
//! and steps them in parallel, one stepping thread per job. This lifts
//! the paper's Algorithm-3 asynchrony idea from intra-run (thread groups
//! vs the barrier) to cross-job (grids vs the launch guard): N tenants no
//! longer serialize on one grid-in-flight. [`JobScheduler::batch_steps`]
//! additionally batches `k` iterations per scheduling round through
//! [`Run::step_many`], amortizing per-step dispatch overhead at the cost
//! of batch-granular telemetry and termination checks (the explicit
//! `max_iter` step cap is still honored exactly — batches are clamped to
//! it).
//!
//! **Determinism.** Because a `Run` owns its whole mutable state and a
//! grid launch never spans runs, a job's trajectory is bit-identical
//! whether it runs alone, interleaved on one stream, or concurrently
//! across streams under any policy and batch size — for the bit-exact
//! engines (CPU, Reduction, Loop-Unrolling, Queue). Queue-Lock and
//! Async-Persistent carry their documented intra-run races, but those
//! races are confined to the job's own `Run`: neighbours still cannot
//! perturb each other. `rust/tests/scheduler_determinism.rs` enforces the
//! bit-exact half.
//!
//! This is the ROADMAP's "many concurrent optimization jobs" seam: PSO-PS
//! (arXiv:2009.03816) treats PSO as a long-lived service, and
//! time-critical deployments (arXiv:1401.0546) need early termination and
//! bounded per-step latency — both fall out of step-wise runs plus this
//! scheduler.

use crate::config::{EngineKind, JobConfig};
use crate::engine::{self, ParallelSettings, Run, StepReport};
use crate::exec::GridPool;
use crate::fitness::{by_name, Fitness, Objective};
use crate::pso::{PsoParams, RunOutput};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// When to stop a job before its `params.max_iter` budget.
///
/// All bounds are optional and combined with OR: the first one hit wins.
/// The run's own iteration budget always applies on top.
#[derive(Debug, Clone, Default)]
pub struct TerminationCriteria {
    /// Hard cap on scheduler steps (iterations) for this job.
    pub max_iter: Option<u64>,
    /// Stop once the global best is at least this good (`>=` under
    /// Maximize, `<=` under Minimize).
    pub target_fit: Option<f64>,
    /// Stop after this many consecutive steps without a global-best
    /// improvement.
    pub stall_window: Option<u64>,
}

impl TerminationCriteria {
    /// No early termination: run to the iteration budget.
    pub fn none() -> Self {
        Self::default()
    }

    /// Builder: cap scheduler steps.
    pub fn with_max_iter(mut self, steps: u64) -> Self {
        self.max_iter = Some(steps);
        self
    }

    /// Builder: stop at a target fitness.
    pub fn with_target_fit(mut self, fit: f64) -> Self {
        self.target_fit = Some(fit);
        self
    }

    /// Builder: stop after a stall.
    pub fn with_stall_window(mut self, steps: u64) -> Self {
        self.stall_window = Some(steps);
        self
    }

    /// Evaluate the criteria after a step. `steps` counts executed steps,
    /// `stalled` counts consecutive non-improving steps, `gbest` is the
    /// job's current best under `objective`.
    pub fn check(
        &self,
        objective: Objective,
        gbest: f64,
        steps: u64,
        stalled: u64,
    ) -> Option<StopReason> {
        if let Some(target) = self.target_fit {
            // Reached when the target is not strictly better than gbest.
            if !objective.better(target, gbest) {
                return Some(StopReason::TargetReached);
            }
        }
        if let Some(cap) = self.max_iter {
            if steps >= cap {
                return Some(StopReason::MaxIter);
            }
        }
        if let Some(window) = self.stall_window {
            if stalled >= window {
                return Some(StopReason::Stalled);
            }
        }
        None
    }
}

/// Why a job stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The run's own `params.max_iter` budget is spent.
    Exhausted,
    /// [`TerminationCriteria::target_fit`] reached.
    TargetReached,
    /// [`TerminationCriteria::max_iter`] cap hit.
    MaxIter,
    /// [`TerminationCriteria::stall_window`] consecutive stale steps.
    Stalled,
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            StopReason::Exhausted => "exhausted",
            StopReason::TargetReached => "target-reached",
            StopReason::MaxIter => "max-iter",
            StopReason::Stalled => "stalled",
        };
        f.write_str(s)
    }
}

/// One tenant job: engine kind, workload, seed, and stop bounds.
pub struct JobSpec {
    /// Display name (batch-config section name).
    pub name: String,
    /// Plane-A engine kind driving this job.
    pub engine: EngineKind,
    /// The workload.
    pub params: PsoParams,
    /// Fitness function (shared, engines borrow it per step).
    pub fitness: Arc<dyn Fitness + Send>,
    /// Optimization sense.
    pub objective: Objective,
    /// Master seed.
    pub seed: u64,
    /// Early-termination bounds.
    pub termination: TerminationCriteria,
    /// Step budget this job would like to finish within — consumed by
    /// [`SchedPolicy::EarliestDeadlineFirst`]; ignored by round-robin.
    pub deadline: Option<u64>,
}

impl JobSpec {
    /// A job with default objective/termination (run to budget).
    pub fn new(
        name: &str,
        engine: EngineKind,
        params: PsoParams,
        fitness: Arc<dyn Fitness + Send>,
        objective: Objective,
        seed: u64,
    ) -> Self {
        Self {
            name: name.to_string(),
            engine,
            params,
            fitness,
            objective,
            seed,
            termination: TerminationCriteria::none(),
            deadline: None,
        }
    }

    /// Build a spec from a batch-config job entry.
    pub fn from_config(cfg: &JobConfig) -> Result<Self> {
        let fitness = by_name(&cfg.fitness)
            .with_context(|| format!("job {}: unknown fitness {}", cfg.name, cfg.fitness))?;
        if !cfg.engine.is_plane_a() {
            bail!(
                "job {}: engine {} is not schedulable (Plane-A only)",
                cfg.name,
                cfg.engine
            );
        }
        let objective = cfg.objective.unwrap_or(fitness.default_objective());
        let params = PsoParams::for_fitness(
            fitness.as_ref(),
            cfg.particles,
            cfg.dim,
            cfg.iters,
            cfg.vmax_frac,
        );
        Ok(Self {
            name: cfg.name.clone(),
            engine: cfg.engine,
            params,
            fitness: Arc::from(fitness),
            objective,
            seed: cfg.seed,
            termination: TerminationCriteria {
                max_iter: cfg.max_steps,
                target_fit: cfg.target_fitness,
                stall_window: cfg.stall_window,
            },
            deadline: cfg.deadline,
        })
    }
}

/// Which live job gets the next step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Fair progress: schedule the least-progressed live jobs first
    /// (ties → lowest index). With a single stream this is exactly the
    /// classic cycle-through-live-jobs-one-step-each order; with S
    /// streams it fills every round with up to S jobs while keeping the
    /// jobs of a contended stream within one round of each other.
    #[default]
    RoundRobin,
    /// Greedy EDF: always step the live job with the smallest remaining
    /// deadline slack (`deadline - steps_done`; jobs without a deadline
    /// rank last). Ties break on job index, so scheduling is fully
    /// deterministic.
    EarliestDeadlineFirst,
}

impl SchedPolicy {
    /// Parse CLI/config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "roundrobin" | "rr" => Some(Self::RoundRobin),
            "edf" | "deadline" | "earliestdeadlinefirst" => Some(Self::EarliestDeadlineFirst),
            _ => None,
        }
    }
}

impl std::fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedPolicy::RoundRobin => f.write_str("round-robin"),
            SchedPolicy::EarliestDeadlineFirst => f.write_str("edf"),
        }
    }
}

/// Telemetry for one scheduling round of one job (with `batch_steps = 1`,
/// one report per executed step).
#[derive(Debug, Clone)]
pub struct JobReport<'a> {
    /// Index of the job in the spec slice.
    pub job: usize,
    /// Job name.
    pub name: &'a str,
    /// Steps (iterations) the job has executed, this round included.
    pub iter: u64,
    /// The job's global-best fitness after the round.
    pub gbest_fit: f64,
    /// Whether any step of the round improved the job's global best.
    pub improved: bool,
    /// Set on the job's final round.
    pub finished: Option<StopReason>,
}

/// Final result of one scheduled job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Job name.
    pub name: String,
    /// Engine kind that ran it.
    pub engine: EngineKind,
    /// Why it stopped.
    pub stop: StopReason,
    /// Steps (iterations) executed.
    pub steps: u64,
    /// The run's output — for the bit-exact engines, identical to the
    /// same job run solo.
    pub output: RunOutput,
}

/// Multiplexes N concurrent jobs over one shared [`GridPool`].
pub struct JobScheduler {
    settings: ParallelSettings,
    policy: SchedPolicy,
    batch_steps: u64,
}

struct LiveJob<'a> {
    run: Box<dyn Run + 'a>,
    steps: u64,
    stalled: u64,
    stop: Option<StopReason>,
    deadline: Option<u64>,
    /// Pool stream this job's launches are pinned to (`job_index % S`).
    stream: usize,
}

impl JobScheduler {
    /// Scheduler over the given pool/geometry (round-robin by default,
    /// one step per scheduling round). A multi-stream pool enables the
    /// concurrent mode (see module docs).
    pub fn new(settings: ParallelSettings) -> Self {
        Self {
            settings,
            policy: SchedPolicy::RoundRobin,
            batch_steps: 1,
        }
    }

    /// Scheduler on a fresh single-stream pool with `workers` threads
    /// (0 = all cores).
    pub fn with_workers(workers: usize) -> Self {
        Self::new(ParallelSettings::with_workers(workers))
    }

    /// Scheduler on a fresh pool with `workers` threads (0 = all cores)
    /// split into `streams` concurrent stream groups.
    pub fn with_streams(workers: usize, streams: usize) -> Self {
        Self::new(ParallelSettings::with_streams(workers, streams))
    }

    /// Override the stepping policy.
    pub fn policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Step each picked job `k` iterations per scheduling round (clamps
    /// to ≥ 1). Batching amortizes per-step dispatch overhead; telemetry,
    /// target-fitness and stall checks become batch-granular, while an
    /// explicit `max_iter` step cap is still honored exactly.
    pub fn batch_steps(mut self, k: u64) -> Self {
        self.batch_steps = k.max(1);
        self
    }

    /// The shared pool jobs are multiplexed over.
    pub fn pool(&self) -> &Arc<GridPool> {
        &self.settings.pool
    }

    /// Jobs stepped in parallel per scheduling round (the pool's stream
    /// count).
    pub fn streams(&self) -> usize {
        self.settings.pool.streams()
    }

    /// Run all jobs to termination, discarding telemetry.
    pub fn run(&self, specs: &[JobSpec]) -> Result<Vec<JobOutcome>> {
        self.run_with(specs, |_| {})
    }

    /// Run all jobs to termination, streaming a [`JobReport`] per
    /// scheduling round and job (= per step when `batch_steps` is 1).
    ///
    /// Outcomes are returned in spec order regardless of completion
    /// order. In concurrent mode (multi-stream pool) the reports of one
    /// round are delivered in job-index order after the whole round
    /// joined, so the telemetry stream stays deterministic.
    pub fn run_with<F: FnMut(&JobReport<'_>)>(
        &self,
        specs: &[JobSpec],
        mut telemetry: F,
    ) -> Result<Vec<JobOutcome>> {
        let streams = self.settings.pool.streams();
        // Prepare every run up front: all allocation happens here, steps
        // stay allocation-free on the hot path. Each job is pinned to the
        // pool stream `index % S` for its whole life.
        let mut engines = Vec::with_capacity(specs.len());
        for (i, spec) in specs.iter().enumerate() {
            let engine = engine::build_with(spec.engine, self.settings.clone().on_stream(i))
                .with_context(|| {
                    format!("job {}: engine {} is not schedulable", spec.name, spec.engine)
                })?;
            engines.push(engine);
        }
        let mut live: Vec<LiveJob<'_>> = Vec::with_capacity(specs.len());
        for (i, (engine, spec)) in engines.iter_mut().zip(specs).enumerate() {
            let fitness: &dyn Fitness = &*spec.fitness;
            live.push(LiveJob {
                run: engine.prepare(&spec.params, fitness, spec.objective, spec.seed),
                steps: 0,
                stalled: 0,
                stop: None,
                deadline: spec.deadline,
                stream: i % streams,
            });
        }

        let mut finished = 0usize;
        while finished < live.len() {
            let picked = match self.policy {
                SchedPolicy::RoundRobin => pick_round_robin(&live, streams),
                SchedPolicy::EarliestDeadlineFirst => pick_edf(&live, streams),
            };
            debug_assert!(!picked.is_empty(), "unfinished job exists");
            let stepped = self.step_round(&mut live, specs, &picked);
            for (idx, report) in stepped {
                let job = &mut live[idx];
                let spec = &specs[idx];
                let executed = report.iter - job.steps;
                job.steps = report.iter;
                if report.improved {
                    job.stalled = 0;
                } else {
                    job.stalled += executed;
                }
                // Criteria outrank budget exhaustion so a target hit on the
                // final iteration still reports TargetReached (matching the
                // precedence TerminationCriteria::check documents).
                let stop = spec
                    .termination
                    .check(spec.objective, report.gbest_fit, job.steps, job.stalled)
                    .or(report.done.then_some(StopReason::Exhausted));
                telemetry(&JobReport {
                    job: idx,
                    name: &spec.name,
                    iter: job.steps,
                    gbest_fit: report.gbest_fit,
                    improved: report.improved,
                    finished: stop,
                });
                if stop.is_some() {
                    job.stop = stop;
                    finished += 1;
                }
            }
        }

        Ok(live
            .into_iter()
            .zip(specs)
            .map(|(job, spec)| JobOutcome {
                name: spec.name.clone(),
                engine: spec.engine,
                stop: job.stop.expect("every job terminated"),
                steps: job.steps,
                output: job.run.finish(),
            })
            .collect())
    }

    /// Step every picked job once (a batch of `batch_steps` iterations),
    /// in parallel when the round holds several jobs — each job's
    /// launches go to its own pool stream, so the grids genuinely
    /// overlap. Returns `(index, report)` pairs sorted by job index.
    fn step_round(
        &self,
        live: &mut [LiveJob<'_>],
        specs: &[JobSpec],
        picked: &[usize],
    ) -> Vec<(usize, StepReport)> {
        if let [idx] = *picked {
            // Serialized fast path (always taken on a single-stream
            // pool): no stepping threads, identical to the pre-stream
            // scheduler loop.
            let k = effective_batch(self.batch_steps, &specs[idx].termination, live[idx].steps);
            return vec![(idx, live[idx].run.step_many(k))];
        }
        let tasks: Vec<(usize, u64, &mut LiveJob<'_>)> = live
            .iter_mut()
            .enumerate()
            .filter(|(i, _)| picked.contains(i))
            .map(|(i, job)| {
                let k = effective_batch(self.batch_steps, &specs[i].termination, job.steps);
                (i, k, job)
            })
            .collect();
        let mut stepped = std::thread::scope(|scope| {
            let mut it = tasks.into_iter();
            let (i0, k0, job0) = it.next().expect("non-empty round");
            let handles: Vec<_> = it
                .map(|(i, k, job)| scope.spawn(move || (i, job.run.step_many(k))))
                .collect();
            // The scheduling thread steps the first job itself: a round of
            // S jobs costs S − 1 spawns.
            let mut out = vec![(i0, job0.run.step_many(k0))];
            for h in handles {
                out.push(h.join().expect("stepping thread panicked"));
            }
            out
        });
        stepped.sort_unstable_by_key(|&(i, _)| i);
        stepped
    }
}

/// Batch size for one job's next round: the configured batch, clamped so
/// an explicit `max_iter` step cap is never overshot (the run's own
/// budget self-limits inside `step_many`).
fn effective_batch(batch: u64, termination: &TerminationCriteria, steps_done: u64) -> u64 {
    match termination.max_iter {
        Some(cap) => batch.min(cap.saturating_sub(steps_done)).max(1),
        None => batch,
    }
}

/// Up to `want` live jobs, least-progressed first (ties → lowest index),
/// no two sharing a pool stream. This is the fair-share generalization of
/// one-step-each cycling to concurrent rounds: with a single stream it
/// degenerates to exactly the classic cyclic order (all live jobs stay
/// within one step of each other, and the least-stepped lowest index is
/// the next cyclic pick), while under stream conflicts the lagging job of
/// a contended stream always outranks its stream-mates, so nobody
/// starves.
fn pick_round_robin(live: &[LiveJob<'_>], want: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..live.len())
        .filter(|&i| live[i].stop.is_none())
        .collect();
    order.sort_unstable_by_key(|&i| (live[i].steps, i));
    take_distinct_streams(live, order, want)
}

/// Up to `want` live jobs by ascending deadline slack (`deadline -
/// steps`; jobs without a deadline rank last, ties break on job index so
/// scheduling is fully deterministic), no two sharing a pool stream.
fn pick_edf(live: &[LiveJob<'_>], want: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..live.len())
        .filter(|&i| live[i].stop.is_none())
        .collect();
    order.sort_unstable_by_key(|&i| {
        let slack = live[i]
            .deadline
            .map(|d| d.saturating_sub(live[i].steps))
            .unwrap_or(u64::MAX);
        (slack, i)
    });
    take_distinct_streams(live, order, want)
}

/// Greedily keep the first `want` entries of `order` whose streams are
/// pairwise distinct (one grid in flight per stream per round).
fn take_distinct_streams(live: &[LiveJob<'_>], order: Vec<usize>, want: usize) -> Vec<usize> {
    let mut picked: Vec<usize> = Vec::with_capacity(want);
    for i in order {
        if picked.iter().any(|&p| live[p].stream == live[i].stream) {
            continue;
        }
        picked.push(i);
        if picked.len() == want {
            break;
        }
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    fn spec(name: &str, engine: EngineKind, n: usize, iters: u64, seed: u64) -> JobSpec {
        JobSpec::new(
            name,
            engine,
            PsoParams::paper_1d(n, iters),
            Arc::new(Cubic),
            Objective::Maximize,
            seed,
        )
    }

    #[test]
    fn criteria_target_fit_respects_objective() {
        let c = TerminationCriteria::none().with_target_fit(10.0);
        let max = Objective::Maximize;
        let min = Objective::Minimize;
        assert_eq!(c.check(max, 9.0, 1, 0), None);
        assert_eq!(c.check(max, 10.0, 1, 0), Some(StopReason::TargetReached));
        assert_eq!(c.check(max, 11.0, 1, 0), Some(StopReason::TargetReached));
        assert_eq!(c.check(min, 11.0, 1, 0), None);
        assert_eq!(c.check(min, 9.0, 1, 0), Some(StopReason::TargetReached));
    }

    #[test]
    fn criteria_max_iter_and_stall() {
        let c = TerminationCriteria::none()
            .with_max_iter(5)
            .with_stall_window(3);
        let max = Objective::Maximize;
        assert_eq!(c.check(max, 0.0, 4, 0), None);
        assert_eq!(c.check(max, 0.0, 5, 0), Some(StopReason::MaxIter));
        assert_eq!(c.check(max, 0.0, 2, 3), Some(StopReason::Stalled));
        // Target outranks the caps when several bounds trip at once.
        let c = c.with_target_fit(f64::NEG_INFINITY);
        assert_eq!(c.check(max, 0.0, 5, 3), Some(StopReason::TargetReached));
    }

    #[test]
    fn policies_parse_and_display() {
        assert_eq!(SchedPolicy::parse("round-robin"), Some(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::parse("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(
            SchedPolicy::parse("EDF"),
            Some(SchedPolicy::EarliestDeadlineFirst)
        );
        assert_eq!(SchedPolicy::parse("fifo"), None);
        assert_eq!(SchedPolicy::RoundRobin.to_string(), "round-robin");
    }

    #[test]
    fn round_robin_interleaves_fairly() {
        let scheduler = JobScheduler::with_workers(2);
        let specs = vec![
            spec("a", EngineKind::Queue, 64, 10, 1),
            spec("b", EngineKind::Queue, 64, 10, 2),
        ];
        let mut order = Vec::new();
        let outcomes = scheduler
            .run_with(&specs, |r| order.push(r.job))
            .unwrap();
        // Strict alternation: a b a b …
        for (k, &j) in order.iter().enumerate() {
            assert_eq!(j, k % 2, "step {k} went to job {j}");
        }
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert_eq!(o.steps, 10);
            assert_eq!(o.stop, StopReason::Exhausted);
            assert_eq!(o.output.iters, 10);
        }
    }

    #[test]
    fn edf_runs_tight_deadlines_first() {
        let scheduler = JobScheduler::with_workers(2).policy(SchedPolicy::EarliestDeadlineFirst);
        let mut a = spec("loose", EngineKind::Queue, 64, 8, 1);
        a.deadline = Some(100);
        let mut b = spec("tight", EngineKind::Queue, 64, 8, 2);
        b.deadline = Some(8);
        let specs = vec![a, b];
        let mut finish_order = Vec::new();
        scheduler
            .run_with(&specs, |r| {
                if r.finished.is_some() {
                    finish_order.push(r.job);
                }
            })
            .unwrap();
        assert_eq!(finish_order, vec![1, 0], "tight deadline must finish first");
    }

    #[test]
    fn from_config_respects_vmax_frac() {
        // Regression: vmax_frac used to be hard-coded to 0.5, silently
        // ignoring the batch TOML. A non-default value must change both
        // the derived velocity clamp and the resulting trajectory.
        let mk = |vmax_frac: f64, name: &str| JobConfig {
            name: name.to_string(),
            fitness: "sphere".into(),
            objective: None,
            particles: 64,
            dim: 3,
            iters: 25,
            engine: EngineKind::Queue,
            vmax_frac,
            seed: 7,
            target_fitness: None,
            stall_window: None,
            max_steps: None,
            deadline: None,
        };
        let tight = JobSpec::from_config(&mk(0.05, "tight")).unwrap();
        let wide = JobSpec::from_config(&mk(0.5, "wide")).unwrap();
        // Sphere domain is [-100, 100] → range 200.
        assert_eq!(tight.params.max_v, 10.0);
        assert_eq!(wide.params.max_v, 100.0);
        let scheduler = JobScheduler::with_workers(2);
        let outs = scheduler.run(&[tight, wide]).unwrap();
        assert_ne!(
            outs[0].output.history, outs[1].output.history,
            "vmax_frac did not reach the trajectory"
        );
    }

    #[test]
    fn concurrent_streams_complete_all_jobs() {
        // Smoke for the concurrent mode: more jobs than streams, mixed
        // shapes, both policies — everything must terminate correctly.
        for policy in [SchedPolicy::RoundRobin, SchedPolicy::EarliestDeadlineFirst] {
            let scheduler = JobScheduler::with_streams(2, 3).policy(policy);
            assert_eq!(scheduler.streams(), 3);
            let specs: Vec<JobSpec> = (0..7)
                .map(|j| spec(&format!("j{j}"), EngineKind::Queue, 64, 5 + j as u64, j as u64))
                .collect();
            let outcomes = scheduler.run(&specs).unwrap();
            for (j, o) in outcomes.iter().enumerate() {
                assert_eq!(o.stop, StopReason::Exhausted, "{policy} {}", o.name);
                assert_eq!(o.steps, 5 + j as u64, "{policy} {}", o.name);
                assert_eq!(o.output.iters, o.steps);
            }
        }
    }

    #[test]
    fn batch_steps_amortize_but_honor_the_step_cap() {
        // batch = 8 over a 20-iteration budget: three rounds, exact total.
        let scheduler = JobScheduler::with_workers(2).batch_steps(8);
        let specs = vec![spec("batched", EngineKind::Queue, 64, 20, 1)];
        let mut rounds = Vec::new();
        let outcomes = scheduler
            .run_with(&specs, |r| rounds.push(r.iter))
            .unwrap();
        assert_eq!(rounds, vec![8, 16, 20], "batch boundaries");
        assert_eq!(outcomes[0].steps, 20);
        assert_eq!(outcomes[0].output.iters, 20);
        // An explicit max_iter criterion is clamped to exactly, even
        // mid-batch.
        let mut capped = spec("capped", EngineKind::Queue, 64, 100, 2);
        capped.termination = TerminationCriteria::none().with_max_iter(11);
        let outcomes = JobScheduler::with_workers(2)
            .batch_steps(8)
            .run(&[capped])
            .unwrap();
        assert_eq!(outcomes[0].stop, StopReason::MaxIter);
        assert_eq!(outcomes[0].steps, 11);
        assert_eq!(outcomes[0].output.iters, 11);
    }

    #[test]
    fn round_robin_with_streams_is_fair_within_a_contended_stream() {
        // 3 jobs on 2 streams: jobs 0 and 2 share stream 0, so a round
        // can schedule at most one of them. Least-progressed-first must
        // keep the stream-mates within one step of each other for the
        // whole run (job 1, alone on stream 1, legitimately runs every
        // round).
        let scheduler = JobScheduler::with_streams(2, 2);
        let specs: Vec<JobSpec> = (0..3)
            .map(|j| spec(&format!("j{j}"), EngineKind::Queue, 64, 12, j as u64))
            .collect();
        let mut steps = [0i64; 3];
        let outcomes = scheduler
            .run_with(&specs, |r| {
                steps[r.job] += 1;
                assert!(
                    (steps[0] - steps[2]).abs() <= 1,
                    "stream-0 mates drifted: {steps:?}"
                );
            })
            .unwrap();
        for o in &outcomes {
            assert_eq!(o.steps, 12);
        }
    }

    #[test]
    fn xla_kinds_are_rejected() {
        let scheduler = JobScheduler::with_workers(1);
        let mut s = spec("x", EngineKind::Queue, 8, 2, 1);
        s.engine = EngineKind::XlaSync;
        let err = scheduler.run(&[s]).unwrap_err().to_string();
        assert!(err.contains("not schedulable"), "{err}");
    }

    #[test]
    fn empty_spec_list_is_fine() {
        let scheduler = JobScheduler::with_workers(1);
        assert!(scheduler.run(&[]).unwrap().is_empty());
    }
}
