//! Multi-job scheduler — many concurrent PSO jobs multiplexed over one
//! shared [`GridPool`].
//!
//! The step-wise engine core ([`crate::engine::Run`]) makes a run a
//! resumable object: all buffers live in the `Run`, a `step()` advances
//! one iteration, and nothing about the trajectory depends on *when* the
//! step executes. [`JobScheduler`] exploits exactly that: it prepares one
//! `Run` per [`JobSpec`], then interleaves single steps over the shared
//! worker pool under a [`SchedPolicy`] until every job hits a
//! [`TerminationCriteria`] bound or exhausts its iteration budget.
//!
//! **Concurrent streams.** When the shared pool is built with `S > 1`
//! stream groups ([`crate::exec::GridPool::with_streams`]), the scheduler
//! runs in concurrent mode: each job is pinned to pool stream
//! `job_index % S` at prepare time, and every scheduling round picks up
//! to `S` live jobs — under the same policy, no two sharing a stream —
//! and steps them in parallel. This lifts the paper's Algorithm-3
//! asynchrony idea from intra-run (thread groups vs the barrier) to
//! cross-job (grids vs the launch guard): N tenants no longer serialize
//! on one grid-in-flight. [`JobScheduler::batch_steps`] additionally
//! batches `k` iterations per scheduling round through
//! [`crate::engine::Run::step_many`], amortizing per-step dispatch overhead at the cost
//! of batch-granular telemetry and termination checks (the explicit
//! `max_iter` step cap is still honored exactly — batches are clamped to
//! it).
//!
//! **Persistent executors & the allocation-free steady state.** A
//! concurrent round is stepped by S−1 long-lived per-stream executor
//! threads (`executor`-module docs) that receive `(run, k)` commands
//! over command slots with the pool's spin-then-park discipline — a
//! round is a publish + wake, not a spawn + join, removing the
//! scheduler-level "launch overhead" (`benches/scheduler_latency.rs`
//! measures the difference against the legacy
//! [`JobScheduler::spawn_per_round`] path, which is kept as the
//! baseline). All round bookkeeping lives in buffers allocated once per
//! session, so a warmed-up scheduling round performs **zero heap
//! allocations** when nothing improves and nothing is preempted
//! (`rust/tests/zero_alloc.rs`).
//!
//! **Dynamic sessions.** The session loop is a first-class object
//! ([`Session`], opened via [`JobScheduler::session`]): jobs live in
//! recyclable slots and can be **admitted**, **cancelled** and
//! **reaped** at round boundaries while the session runs — the seam the
//! [`crate::service`] daemon is built on. Job names are unique identity
//! keys; duplicate admission is a loud error. The fixed-batch entry
//! points below drive the same session type, so the two paths cannot
//! drift.
//!
//! **Determinism.** Because a `Run` owns its whole mutable state and a
//! grid launch never spans runs, a job's trajectory is bit-identical
//! whether it runs alone, interleaved on one stream, or concurrently
//! across streams under any policy and batch size — for the bit-exact
//! engines (CPU, Reduction, Loop-Unrolling, Queue). Queue-Lock and
//! Async-Persistent carry their documented intra-run races, but those
//! races are confined to the job's own `Run`: neighbours still cannot
//! perturb each other. Admission and cancellation only happen at round
//! boundaries (grid-quiescent, every run at a step boundary), so the
//! invariant extends to live traffic: a job's trajectory does not depend
//! on *when* other jobs were admitted or cancelled around it.
//! `rust/tests/scheduler_determinism.rs` enforces the bit-exact half.
//!
//! **Preemption & migration.** Runs are checkpointable
//! ([`crate::engine::Run::checkpoint`]), which upgrades the scheduler
//! from cooperative interleaving to true preemptive multi-tenancy: with
//! [`JobScheduler::preempt_quantum`] set and more live jobs than
//! streams, a job that has run its quantum is **suspended** to a
//! [`RunCheckpoint`] (its buffers freed), and when the policy next picks
//! it, it is **restored onto whichever stream is free that round** —
//! migration. [`JobScheduler::run_session`] additionally bounds a whole
//! batch to `max_rounds` scheduling rounds and returns a
//! [`BatchRun::Suspended`] snapshot of every job ([`JobCheckpoint`]),
//! which a later session — same process or another one, via the
//! `cupso batch --checkpoint-dir` / `cupso resume` round-trip — resumes.
//! Because restore is bit-exact for the bit-exact engines, *any*
//! suspend/restore/migrate schedule yields bit-identical per-job results
//! (`rust/tests/checkpoint_resume.rs`).
//!
//! This is the ROADMAP's "many concurrent optimization jobs" seam: PSO-PS
//! (arXiv:2009.03816) treats PSO as a long-lived service, and
//! time-critical deployments (arXiv:1401.0546) need early termination and
//! bounded per-step latency — both fall out of step-wise runs plus this
//! scheduler.
//!
//! [`RunCheckpoint`]: crate::checkpoint::RunCheckpoint

pub(crate) mod executor;
mod session;

pub use session::{JobView, Session};

use crate::checkpoint::JobCheckpoint;
use crate::config::{EngineKind, JobConfig};
use crate::engine::ParallelSettings;
use crate::exec::GridPool;
use crate::fitness::{by_name, Fitness, Objective};
use crate::pso::{PsoParams, RunOutput};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// When to stop a job before its `params.max_iter` budget.
///
/// All bounds are optional and combined with OR: the first one hit wins.
/// The run's own iteration budget always applies on top.
#[derive(Debug, Clone, Default)]
pub struct TerminationCriteria {
    /// Hard cap on scheduler steps (iterations) for this job.
    pub max_iter: Option<u64>,
    /// Stop once the global best is at least this good (`>=` under
    /// Maximize, `<=` under Minimize).
    pub target_fit: Option<f64>,
    /// Stop after this many consecutive steps without a global-best
    /// improvement.
    pub stall_window: Option<u64>,
}

impl TerminationCriteria {
    /// No early termination: run to the iteration budget.
    pub fn none() -> Self {
        Self::default()
    }

    /// Builder: cap scheduler steps.
    pub fn with_max_iter(mut self, steps: u64) -> Self {
        self.max_iter = Some(steps);
        self
    }

    /// Builder: stop at a target fitness.
    pub fn with_target_fit(mut self, fit: f64) -> Self {
        self.target_fit = Some(fit);
        self
    }

    /// Builder: stop after a stall.
    pub fn with_stall_window(mut self, steps: u64) -> Self {
        self.stall_window = Some(steps);
        self
    }

    /// Evaluate the criteria after a step. `steps` counts executed steps,
    /// `stalled` counts consecutive non-improving steps, `gbest` is the
    /// job's current best under `objective`.
    pub fn check(
        &self,
        objective: Objective,
        gbest: f64,
        steps: u64,
        stalled: u64,
    ) -> Option<StopReason> {
        if let Some(target) = self.target_fit {
            // Reached when the target is not strictly better than gbest.
            if !objective.better(target, gbest) {
                return Some(StopReason::TargetReached);
            }
        }
        if let Some(cap) = self.max_iter {
            if steps >= cap {
                return Some(StopReason::MaxIter);
            }
        }
        if let Some(window) = self.stall_window {
            if stalled >= window {
                return Some(StopReason::Stalled);
            }
        }
        None
    }
}

/// Why a job stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The run's own `params.max_iter` budget is spent.
    Exhausted,
    /// [`TerminationCriteria::target_fit`] reached.
    TargetReached,
    /// [`TerminationCriteria::max_iter`] cap hit.
    MaxIter,
    /// [`TerminationCriteria::stall_window`] consecutive stale steps.
    Stalled,
    /// Cancelled by a tenant at a round boundary ([`Session::cancel`] /
    /// the service's `cancel` verb).
    Cancelled,
}

impl StopReason {
    /// Stable wire code for [`JobCheckpoint::stop`] (version-1 format —
    /// never renumber; new reasons append new codes).
    pub fn code(self) -> u8 {
        match self {
            StopReason::Exhausted => 0,
            StopReason::TargetReached => 1,
            StopReason::MaxIter => 2,
            StopReason::Stalled => 3,
            StopReason::Cancelled => 4,
        }
    }

    /// Inverse of [`code`](Self::code).
    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => StopReason::Exhausted,
            1 => StopReason::TargetReached,
            2 => StopReason::MaxIter,
            3 => StopReason::Stalled,
            4 => StopReason::Cancelled,
            other => bail!("unknown stop-reason code {other}"),
        })
    }
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            StopReason::Exhausted => "exhausted",
            StopReason::TargetReached => "target-reached",
            StopReason::MaxIter => "max-iter",
            StopReason::Stalled => "stalled",
            StopReason::Cancelled => "cancelled",
        };
        f.write_str(s)
    }
}

/// One tenant job: engine kind, workload, seed, and stop bounds.
#[derive(Clone)]
pub struct JobSpec {
    /// Display name (batch-config section name). Interned (`Arc<str>`) so
    /// telemetry, outcomes and checkpoint snapshots share one allocation
    /// instead of cloning the string per round/persist. Names are
    /// **unique identity keys**: the scheduler rejects duplicate
    /// admissions and the service addresses jobs by name.
    pub name: Arc<str>,
    /// Plane-A engine kind driving this job.
    pub engine: EngineKind,
    /// The workload.
    pub params: PsoParams,
    /// Fitness function (shared, engines borrow it per step).
    pub fitness: Arc<dyn Fitness + Send>,
    /// Optimization sense.
    pub objective: Objective,
    /// Master seed.
    pub seed: u64,
    /// Early-termination bounds.
    pub termination: TerminationCriteria,
    /// Step budget this job would like to finish within — consumed by
    /// [`SchedPolicy::EarliestDeadlineFirst`]; ignored by round-robin.
    pub deadline: Option<u64>,
    /// Owning tenant (interned) — consumed by
    /// [`SchedPolicy::WeightedFair`] and the service's per-tenant
    /// admission quotas. `None` jobs share one anonymous tenant.
    pub tenant: Option<Arc<str>>,
}

impl JobSpec {
    /// A job with default objective/termination (run to budget).
    pub fn new(
        name: &str,
        engine: EngineKind,
        params: PsoParams,
        fitness: Arc<dyn Fitness + Send>,
        objective: Objective,
        seed: u64,
    ) -> Self {
        Self {
            name: Arc::from(name),
            engine,
            params,
            fitness,
            objective,
            seed,
            termination: TerminationCriteria::none(),
            deadline: None,
            tenant: None,
        }
    }

    /// Build a spec from a batch-config job entry.
    pub fn from_config(cfg: &JobConfig) -> Result<Self> {
        let fitness = by_name(&cfg.fitness)
            .with_context(|| format!("job {}: unknown fitness {}", cfg.name, cfg.fitness))?;
        if !cfg.engine.is_plane_a() {
            bail!(
                "job {}: engine {} is not schedulable (Plane-A only)",
                cfg.name,
                cfg.engine
            );
        }
        let objective = cfg.objective.unwrap_or(fitness.default_objective());
        let params = PsoParams::for_fitness(
            fitness.as_ref(),
            cfg.particles,
            cfg.dim,
            cfg.iters,
            cfg.vmax_frac,
        );
        Ok(Self {
            name: cfg.name.as_str().into(),
            engine: cfg.engine,
            params,
            fitness: Arc::from(fitness),
            objective,
            seed: cfg.seed,
            termination: TerminationCriteria {
                max_iter: cfg.max_steps,
                target_fit: cfg.target_fitness,
                stall_window: cfg.stall_window,
            },
            deadline: cfg.deadline,
            tenant: cfg.tenant.as_deref().map(Arc::from),
        })
    }

    /// Rebuild a spec from a suspended job checkpoint: workload, engine,
    /// seed and objective come from the run state; fitness and the
    /// termination bounds from the job wrapper. This is how `cupso
    /// resume` (and a drained service) reconstructs a batch purely from
    /// its snapshot. Tenancy is service-session state, not run state, so
    /// a resumed spec starts with no tenant.
    pub fn from_checkpoint(ckpt: &JobCheckpoint) -> Result<Self> {
        let fitness = by_name(&ckpt.fitness)
            .with_context(|| format!("job {}: unknown fitness {:?}", ckpt.name, ckpt.fitness))?;
        let engine = ckpt.run.kind.engine_kind().with_context(|| {
            format!(
                "job {}: run kind {} is not schedulable",
                ckpt.name, ckpt.run.kind
            )
        })?;
        let mut spec = JobSpec::new(
            &ckpt.name,
            engine,
            ckpt.run.params.clone(),
            Arc::from(fitness),
            ckpt.run.objective,
            ckpt.run.seed,
        );
        spec.termination = TerminationCriteria {
            max_iter: ckpt.max_steps,
            target_fit: ckpt.target_fit,
            stall_window: ckpt.stall_window,
        };
        spec.deadline = ckpt.deadline;
        Ok(spec)
    }
}

/// Which live job gets the next step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Fair progress: schedule the least-progressed live jobs first
    /// (ties → lowest index). With a single stream this is exactly the
    /// classic cycle-through-live-jobs-one-step-each order; with S
    /// streams it fills every round with up to S jobs while keeping the
    /// jobs of a contended stream within one round of each other.
    #[default]
    RoundRobin,
    /// Greedy EDF: always step the live job with the smallest remaining
    /// deadline slack (`deadline - steps_done`; jobs without a deadline
    /// rank last). Ties break on job index, so scheduling is fully
    /// deterministic.
    EarliestDeadlineFirst,
    /// Tenant-fair progress: schedule the job whose **tenant** has
    /// executed the fewest total steps first (ties → least-progressed
    /// job, then lowest index). A tenant with ten live jobs advances no
    /// faster than a tenant with one, so one heavy tenant cannot starve
    /// the rest of a shared service. Jobs without a tenant share one
    /// anonymous tenant. Fully deterministic: the key is
    /// `(tenant steps, job steps, slot index)`, all integers.
    WeightedFair,
}

impl SchedPolicy {
    /// Parse CLI/config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "roundrobin" | "rr" => Some(Self::RoundRobin),
            "edf" | "deadline" | "earliestdeadlinefirst" => Some(Self::EarliestDeadlineFirst),
            "weightedfair" | "wf" | "fair" => Some(Self::WeightedFair),
            _ => None,
        }
    }
}

impl std::fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedPolicy::RoundRobin => f.write_str("round-robin"),
            SchedPolicy::EarliestDeadlineFirst => f.write_str("edf"),
            SchedPolicy::WeightedFair => f.write_str("weighted-fair"),
        }
    }
}

/// Telemetry for one scheduling round of one job (with `batch_steps = 1`,
/// one report per executed step).
#[derive(Debug, Clone)]
pub struct JobReport<'a> {
    /// Slot index of the job (== index in the spec slice for the
    /// fixed-batch entry points).
    pub job: usize,
    /// Job name.
    pub name: &'a str,
    /// Steps (iterations) the job has executed, this round included.
    pub iter: u64,
    /// The job's global-best fitness after the round.
    pub gbest_fit: f64,
    /// Whether any step of the round improved the job's global best.
    pub improved: bool,
    /// Set on the job's final round.
    pub finished: Option<StopReason>,
}

/// Final result of one scheduled job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Job name (shared with the spec's interned name).
    pub name: Arc<str>,
    /// Engine kind that ran it.
    pub engine: EngineKind,
    /// Why it stopped.
    pub stop: StopReason,
    /// Steps (iterations) executed.
    pub steps: u64,
    /// The run's output — for the bit-exact engines, identical to the
    /// same job run solo.
    pub output: RunOutput,
}

/// Result of one scheduling session ([`JobScheduler::run_session`]).
pub enum BatchRun {
    /// Every job terminated; outcomes in spec order.
    Complete(Vec<JobOutcome>),
    /// The round cap fired first: one [`JobCheckpoint`] per job (spec
    /// order, finished jobs included with their stop reason), ready to be
    /// persisted and resumed — in this process or another.
    Suspended(Vec<JobCheckpoint>),
}

/// Multiplexes N concurrent jobs over one shared [`GridPool`].
pub struct JobScheduler {
    settings: ParallelSettings,
    policy: SchedPolicy,
    batch_steps: u64,
    /// Preemption quantum in steps (`None` = cooperative scheduling).
    preempt_quantum: Option<u64>,
    /// Step concurrent rounds with per-round scoped threads instead of
    /// the persistent executors (the legacy baseline; see
    /// [`JobScheduler::spawn_per_round`]).
    spawn_per_round: bool,
    /// Enable swarm-packing (see [`JobScheduler::pack`]).
    pack: bool,
    /// Smallest group worth packing (see [`JobScheduler::pack_min`]).
    pack_min: usize,
    /// Largest pack formed (see [`JobScheduler::pack_max`]; 0 = unbounded).
    pack_max: usize,
}

impl JobScheduler {
    /// Scheduler over the given pool/geometry (round-robin by default,
    /// one step per scheduling round). A multi-stream pool enables the
    /// concurrent mode (see module docs).
    pub fn new(settings: ParallelSettings) -> Self {
        Self {
            settings,
            policy: SchedPolicy::RoundRobin,
            batch_steps: 1,
            preempt_quantum: None,
            spawn_per_round: false,
            pack: false,
            pack_min: 2,
            pack_max: 0,
        }
    }

    /// Scheduler on a fresh single-stream pool with `workers` threads
    /// (0 = all cores).
    pub fn with_workers(workers: usize) -> Self {
        Self::new(ParallelSettings::with_workers(workers))
    }

    /// Scheduler on a fresh pool with `workers` threads (0 = all cores)
    /// split into `streams` concurrent stream groups.
    pub fn with_streams(workers: usize, streams: usize) -> Self {
        Self::new(ParallelSettings::with_streams(workers, streams))
    }

    /// Override the stepping policy.
    pub fn policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Step each picked job `k` iterations per scheduling round (clamps
    /// to ≥ 1). Batching amortizes per-step dispatch overhead; telemetry,
    /// target-fitness and stall checks become batch-granular, while an
    /// explicit `max_iter` step cap is still honored exactly.
    pub fn batch_steps(mut self, k: u64) -> Self {
        self.batch_steps = k.max(1);
        self
    }

    /// Enable preemptive scheduling: when live jobs outnumber streams, a
    /// job that has executed `quantum` steps since its last activation is
    /// suspended to a checkpoint after its round, freeing its buffers;
    /// the policy later restores it onto whichever stream is free
    /// (migration). `0` disables preemption (the default, cooperative
    /// mode). Bit-exact engines produce bit-identical results under any
    /// quantum — preemption only changes *where and when* work happens.
    pub fn preempt_quantum(mut self, quantum: u64) -> Self {
        self.preempt_quantum = (quantum > 0).then_some(quantum);
        self
    }

    /// Step concurrent rounds by spawning one scoped OS thread per extra
    /// job per round (the pre-executor behavior) instead of publishing to
    /// the persistent stream executors. The two paths are bit-identical
    /// for every engine (`rust/tests/scheduler_determinism.rs`); this
    /// knob exists so `benches/scheduler_latency.rs` can measure the
    /// per-round fixed cost the executors remove. Off by default.
    pub fn spawn_per_round(mut self, enabled: bool) -> Self {
        self.spawn_per_round = enabled;
        self
    }

    /// Enable swarm-packing: at round boundaries the session groups
    /// compatible live Queue jobs (same dimensionality, same objective)
    /// into [`crate::engine::PackedRun`] packs — one shared SoA slab
    /// stepping *every* member with a single launch pair per round, so a
    /// fleet of small jobs stops paying the per-job dispatch cost
    /// (`benches/pack_throughput.rs`). Packing is purely an execution
    /// layout: bit-exact with solo execution, per-job status/cancel/
    /// checkpoint semantics unchanged
    /// (`rust/tests/scheduler_determinism.rs` § pack). Off by default.
    pub fn pack(mut self, enabled: bool) -> Self {
        self.pack = enabled;
        self
    }

    /// Smallest compatible group worth packing (clamps to ≥ 2; default
    /// 2). Groups below the minimum run standalone, and a pack whose
    /// live membership falls below it is dissolved back to standalone
    /// jobs at the next round boundary.
    pub fn pack_min(mut self, n: usize) -> Self {
        self.pack_min = n.max(2);
        self
    }

    /// Largest pack formed (0 = unbounded, the default). A compatible
    /// group larger than the maximum splits into several packs; a
    /// leftover chunk smaller than [`pack_min`](Self::pack_min) stays
    /// standalone (the "admit into a full pack" path).
    pub fn pack_max(mut self, n: usize) -> Self {
        self.pack_max = n;
        self
    }

    /// The shared pool jobs are multiplexed over.
    pub fn pool(&self) -> &Arc<GridPool> {
        &self.settings.pool
    }

    /// Jobs stepped in parallel per scheduling round (the pool's stream
    /// count).
    pub fn streams(&self) -> usize {
        self.settings.pool.streams()
    }

    /// Open a dynamic scheduling session: an empty slot table that jobs
    /// can be admitted into, stepped round by round, cancelled out of,
    /// and snapshotted — the seam the [`crate::service`] daemon drives.
    /// Every fixed-batch entry point below is a loop over this same
    /// session type.
    pub fn session(&self) -> Session {
        Session::new(self)
    }

    /// Run all jobs to termination, discarding telemetry.
    pub fn run(&self, specs: &[JobSpec]) -> Result<Vec<JobOutcome>> {
        self.run_with(specs, |_| {})
    }

    /// Run all jobs to termination, streaming a [`JobReport`] per
    /// scheduling round and job (= per step when `batch_steps` is 1).
    ///
    /// Outcomes are returned in spec order regardless of completion
    /// order. In concurrent mode (multi-stream pool) the reports of one
    /// round are delivered in job-index order after the whole round
    /// joined, so the telemetry stream stays deterministic.
    pub fn run_with<F: FnMut(&JobReport<'_>)>(
        &self,
        specs: &[JobSpec],
        telemetry: F,
    ) -> Result<Vec<JobOutcome>> {
        match self.run_session(specs, None, None, telemetry)? {
            BatchRun::Complete(outcomes) => Ok(outcomes),
            BatchRun::Suspended(_) => unreachable!("an uncapped session cannot suspend"),
        }
    }

    /// The general scheduling entry: run at most `max_rounds` scheduling
    /// rounds (`None` = to termination), optionally continuing from a
    /// prior session's `resume` snapshot (one [`JobCheckpoint`] per spec,
    /// same order and names).
    ///
    /// Resumed jobs start suspended and are restored lazily when the
    /// policy first picks them — onto whichever stream is free that
    /// round, which may differ from their pre-suspension pinning
    /// (migration; also across *sessions* the stream layout may change
    /// entirely, e.g. a different `streams` count). For the bit-exact
    /// engines none of this is observable in the results.
    pub fn run_session<F: FnMut(&JobReport<'_>)>(
        &self,
        specs: &[JobSpec],
        resume: Option<&[JobCheckpoint]>,
        max_rounds: Option<u64>,
        telemetry: F,
    ) -> Result<BatchRun> {
        self.run_session_with(specs, resume, max_rounds, None, |_| Ok(()), telemetry)
    }

    /// [`run_session`](Self::run_session) plus an **in-place periodic
    /// persistence hook**: every `persist_every` rounds the session hands
    /// a full batch snapshot (same shape as [`BatchRun::Suspended`]) to
    /// `persist` and *keeps running* — the run buffers stay live, nothing
    /// is suspended or reallocated, and the relaxed engines'
    /// interleavings are not perturbed. This is what
    /// `cupso batch --checkpoint-dir --checkpoint-every` uses; the old
    /// behavior (suspend the whole batch per period, then resume it) paid
    /// a full teardown + restore per checkpoint.
    ///
    /// A `persist` error aborts the session (the batch state is lost to
    /// this process but the last persisted snapshot survives on disk).
    pub fn run_session_with<F, P>(
        &self,
        specs: &[JobSpec],
        resume: Option<&[JobCheckpoint]>,
        max_rounds: Option<u64>,
        persist_every: Option<u64>,
        mut persist: P,
        mut telemetry: F,
    ) -> Result<BatchRun>
    where
        F: FnMut(&JobReport<'_>),
        P: FnMut(&[JobCheckpoint]) -> Result<()>,
    {
        // Fixed-batch driving of the dynamic Session: admit everything up
        // front — all allocation happens here, rounds stay allocation-free
        // on the hot path — then loop rounds to termination. Slot order ==
        // spec order, so outcomes, snapshots and telemetry indices are
        // exactly the pre-Session behavior.
        let mut session = self.session();
        match resume {
            None => {
                for spec in specs {
                    session.admit(spec.clone())?;
                }
            }
            Some(ckpts) => {
                if ckpts.len() != specs.len() {
                    bail!(
                        "resume snapshot has {} jobs, specs have {}",
                        ckpts.len(),
                        specs.len()
                    );
                }
                for (spec, ckpt) in specs.iter().zip(ckpts) {
                    session.admit_resumed(spec.clone(), ckpt)?;
                }
            }
        }

        let mut rounds = 0u64;
        while session.live() > 0 {
            if max_rounds.is_some_and(|cap| rounds >= cap) {
                return Ok(BatchRun::Suspended(session.snapshot()));
            }
            rounds += 1;
            session.round(&mut telemetry)?;
            // Skip the hook when the next iteration will suspend anyway:
            // the suspension snapshot captures the identical state, and a
            // back-to-back duplicate would waste a retention slot.
            let suspending_next = max_rounds.is_some_and(|cap| rounds >= cap);
            if persist_every.is_some_and(|n| rounds % n == 0)
                && session.live() > 0
                && !suspending_next
            {
                persist(&session.snapshot())?;
            }
        }
        Ok(BatchRun::Complete(session.into_outcomes()?))
    }
}

/// Batch size for one job's next round: the configured batch, clamped so
/// an explicit `max_iter` step cap is never overshot (the run's own
/// budget self-limits inside `step_many`).
fn effective_batch(batch: u64, termination: &TerminationCriteria, steps_done: u64) -> u64 {
    match termination.max_iter {
        Some(cap) => batch.min(cap.saturating_sub(steps_done)).max(1),
        None => batch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    fn spec(name: &str, engine: EngineKind, n: usize, iters: u64, seed: u64) -> JobSpec {
        JobSpec::new(
            name,
            engine,
            PsoParams::paper_1d(n, iters),
            Arc::new(Cubic),
            Objective::Maximize,
            seed,
        )
    }

    #[test]
    fn criteria_target_fit_respects_objective() {
        let c = TerminationCriteria::none().with_target_fit(10.0);
        let max = Objective::Maximize;
        let min = Objective::Minimize;
        assert_eq!(c.check(max, 9.0, 1, 0), None);
        assert_eq!(c.check(max, 10.0, 1, 0), Some(StopReason::TargetReached));
        assert_eq!(c.check(max, 11.0, 1, 0), Some(StopReason::TargetReached));
        assert_eq!(c.check(min, 11.0, 1, 0), None);
        assert_eq!(c.check(min, 9.0, 1, 0), Some(StopReason::TargetReached));
    }

    #[test]
    fn criteria_max_iter_and_stall() {
        let c = TerminationCriteria::none()
            .with_max_iter(5)
            .with_stall_window(3);
        let max = Objective::Maximize;
        assert_eq!(c.check(max, 0.0, 4, 0), None);
        assert_eq!(c.check(max, 0.0, 5, 0), Some(StopReason::MaxIter));
        assert_eq!(c.check(max, 0.0, 2, 3), Some(StopReason::Stalled));
        // Target outranks the caps when several bounds trip at once.
        let c = c.with_target_fit(f64::NEG_INFINITY);
        assert_eq!(c.check(max, 0.0, 5, 3), Some(StopReason::TargetReached));
    }

    #[test]
    fn policies_parse_and_display() {
        assert_eq!(SchedPolicy::parse("round-robin"), Some(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::parse("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(
            SchedPolicy::parse("EDF"),
            Some(SchedPolicy::EarliestDeadlineFirst)
        );
        assert_eq!(
            SchedPolicy::parse("weighted-fair"),
            Some(SchedPolicy::WeightedFair)
        );
        assert_eq!(SchedPolicy::parse("wf"), Some(SchedPolicy::WeightedFair));
        assert_eq!(SchedPolicy::parse("fifo"), None);
        assert_eq!(SchedPolicy::RoundRobin.to_string(), "round-robin");
        assert_eq!(SchedPolicy::WeightedFair.to_string(), "weighted-fair");
        // Display → parse round trip for every policy.
        for p in [
            SchedPolicy::RoundRobin,
            SchedPolicy::EarliestDeadlineFirst,
            SchedPolicy::WeightedFair,
        ] {
            assert_eq!(SchedPolicy::parse(&p.to_string()), Some(p));
        }
    }

    #[test]
    fn round_robin_interleaves_fairly() {
        let scheduler = JobScheduler::with_workers(2);
        let specs = vec![
            spec("a", EngineKind::Queue, 64, 10, 1),
            spec("b", EngineKind::Queue, 64, 10, 2),
        ];
        let mut order = Vec::new();
        let outcomes = scheduler
            .run_with(&specs, |r| order.push(r.job))
            .unwrap();
        // Strict alternation: a b a b …
        for (k, &j) in order.iter().enumerate() {
            assert_eq!(j, k % 2, "step {k} went to job {j}");
        }
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert_eq!(o.steps, 10);
            assert_eq!(o.stop, StopReason::Exhausted);
            assert_eq!(o.output.iters, 10);
        }
    }

    #[test]
    fn edf_runs_tight_deadlines_first() {
        let scheduler = JobScheduler::with_workers(2).policy(SchedPolicy::EarliestDeadlineFirst);
        let mut a = spec("loose", EngineKind::Queue, 64, 8, 1);
        a.deadline = Some(100);
        let mut b = spec("tight", EngineKind::Queue, 64, 8, 2);
        b.deadline = Some(8);
        let specs = vec![a, b];
        let mut finish_order = Vec::new();
        scheduler
            .run_with(&specs, |r| {
                if r.finished.is_some() {
                    finish_order.push(r.job);
                }
            })
            .unwrap();
        assert_eq!(finish_order, vec![1, 0], "tight deadline must finish first");
    }

    #[test]
    fn weighted_fair_splits_rounds_by_tenant_not_by_job() {
        // Tenant A brings three jobs, tenant B one: under weighted-fair a
        // single stream must alternate A-job / B-job, giving B half the
        // machine despite owning a quarter of the jobs (round-robin would
        // give it a quarter). The pick order is fully deterministic.
        let mk = |name: &str, tenant: &str, seed: u64| {
            let mut s = spec(name, EngineKind::Queue, 64, 10, seed);
            s.tenant = Some(Arc::from(tenant));
            s
        };
        let specs = vec![
            mk("a1", "A", 1),
            mk("a2", "A", 2),
            mk("a3", "A", 3),
            mk("b1", "B", 4),
        ];
        let scheduler = JobScheduler::with_workers(2).policy(SchedPolicy::WeightedFair);
        let mut order = Vec::new();
        let outcomes = scheduler
            .run_with(&specs, |r| order.push(r.job))
            .unwrap();
        // Tenant sums tie at every even pick, so the sequence interleaves
        // B's only job with A's least-progressed job.
        assert_eq!(&order[..8], &[0, 3, 1, 3, 2, 3, 0, 3], "pick order {order:?}");
        // Every other pick belongs to tenant B until its job finishes.
        let b_picks = order.iter().take(20).filter(|&&j| j == 3).count();
        assert_eq!(b_picks, 10, "tenant B did not get half the rounds: {order:?}");
        for o in &outcomes {
            assert_eq!(o.stop, StopReason::Exhausted, "{}", o.name);
            assert_eq!(o.steps, 10, "{}", o.name);
        }
    }

    #[test]
    fn from_config_respects_vmax_frac() {
        // Regression: vmax_frac used to be hard-coded to 0.5, silently
        // ignoring the batch TOML. A non-default value must change both
        // the derived velocity clamp and the resulting trajectory.
        let mk = |vmax_frac: f64, name: &str| JobConfig {
            name: name.to_string(),
            fitness: "sphere".into(),
            objective: None,
            particles: 64,
            dim: 3,
            iters: 25,
            engine: EngineKind::Queue,
            vmax_frac,
            seed: 7,
            target_fitness: None,
            stall_window: None,
            max_steps: None,
            deadline: None,
            tenant: None,
        };
        let tight = JobSpec::from_config(&mk(0.05, "tight")).unwrap();
        let wide = JobSpec::from_config(&mk(0.5, "wide")).unwrap();
        // Sphere domain is [-100, 100] → range 200.
        assert_eq!(tight.params.max_v, 10.0);
        assert_eq!(wide.params.max_v, 100.0);
        let scheduler = JobScheduler::with_workers(2);
        let outs = scheduler.run(&[tight, wide]).unwrap();
        assert_ne!(
            outs[0].output.history, outs[1].output.history,
            "vmax_frac did not reach the trajectory"
        );
    }

    #[test]
    fn concurrent_streams_complete_all_jobs() {
        // Smoke for the concurrent mode: more jobs than streams, mixed
        // shapes, both policies — everything must terminate correctly.
        for policy in [SchedPolicy::RoundRobin, SchedPolicy::EarliestDeadlineFirst] {
            let scheduler = JobScheduler::with_streams(2, 3).policy(policy);
            assert_eq!(scheduler.streams(), 3);
            let specs: Vec<JobSpec> = (0..7)
                .map(|j| spec(&format!("j{j}"), EngineKind::Queue, 64, 5 + j as u64, j as u64))
                .collect();
            let outcomes = scheduler.run(&specs).unwrap();
            for (j, o) in outcomes.iter().enumerate() {
                assert_eq!(o.stop, StopReason::Exhausted, "{policy} {}", o.name);
                assert_eq!(o.steps, 5 + j as u64, "{policy} {}", o.name);
                assert_eq!(o.output.iters, o.steps);
            }
        }
    }

    #[test]
    fn batch_steps_amortize_but_honor_the_step_cap() {
        // batch = 8 over a 20-iteration budget: three rounds, exact total.
        let scheduler = JobScheduler::with_workers(2).batch_steps(8);
        let specs = vec![spec("batched", EngineKind::Queue, 64, 20, 1)];
        let mut rounds = Vec::new();
        let outcomes = scheduler
            .run_with(&specs, |r| rounds.push(r.iter))
            .unwrap();
        assert_eq!(rounds, vec![8, 16, 20], "batch boundaries");
        assert_eq!(outcomes[0].steps, 20);
        assert_eq!(outcomes[0].output.iters, 20);
        // An explicit max_iter criterion is clamped to exactly, even
        // mid-batch.
        let mut capped = spec("capped", EngineKind::Queue, 64, 100, 2);
        capped.termination = TerminationCriteria::none().with_max_iter(11);
        let outcomes = JobScheduler::with_workers(2)
            .batch_steps(8)
            .run(&[capped])
            .unwrap();
        assert_eq!(outcomes[0].stop, StopReason::MaxIter);
        assert_eq!(outcomes[0].steps, 11);
        assert_eq!(outcomes[0].output.iters, 11);
    }

    #[test]
    fn round_robin_with_streams_is_fair_within_a_contended_stream() {
        // 3 jobs on 2 streams: jobs 0 and 2 share stream 0, so a round
        // can schedule at most one of them. Least-progressed-first must
        // keep the stream-mates within one step of each other for the
        // whole run (job 1, alone on stream 1, legitimately runs every
        // round).
        let scheduler = JobScheduler::with_streams(2, 2);
        let specs: Vec<JobSpec> = (0..3)
            .map(|j| spec(&format!("j{j}"), EngineKind::Queue, 64, 12, j as u64))
            .collect();
        let mut steps = [0i64; 3];
        let outcomes = scheduler
            .run_with(&specs, |r| {
                steps[r.job] += 1;
                assert!(
                    (steps[0] - steps[2]).abs() <= 1,
                    "stream-0 mates drifted: {steps:?}"
                );
            })
            .unwrap();
        for o in &outcomes {
            assert_eq!(o.steps, 12);
        }
    }

    #[test]
    fn preemptive_scheduling_matches_cooperative() {
        // Any quantum, jobs > streams: bit-exact engines must produce the
        // exact cooperative results despite suspend/restore churn.
        let mk = || {
            vec![
                spec("a", EngineKind::Queue, 64, 15, 1),
                spec("b", EngineKind::Queue, 64, 15, 2),
                spec("c", EngineKind::Reduction, 100, 12, 3),
            ]
        };
        let coop = JobScheduler::with_workers(2).run(&mk()).unwrap();
        for quantum in [1u64, 4, 100] {
            let preempted = JobScheduler::with_workers(2)
                .preempt_quantum(quantum)
                .run(&mk())
                .unwrap();
            for (a, b) in coop.iter().zip(&preempted) {
                assert_eq!(a.output.gbest_fit, b.output.gbest_fit, "q={quantum} {}", a.name);
                assert_eq!(a.output.gbest_pos, b.output.gbest_pos, "q={quantum} {}", a.name);
                assert_eq!(a.output.history, b.output.history, "q={quantum} {}", a.name);
                assert_eq!(a.steps, b.steps, "q={quantum} {}", a.name);
            }
        }
    }

    #[test]
    fn session_round_cap_suspends_then_resume_completes_identically() {
        let mk = || {
            vec![
                spec("s1", EngineKind::Queue, 64, 20, 1),
                spec("s2", EngineKind::Queue, 64, 20, 2),
            ]
        };
        let reference = JobScheduler::with_workers(2).run(&mk()).unwrap();
        let scheduler = JobScheduler::with_workers(2);
        let specs = mk();
        let snap = match scheduler.run_session(&specs, None, Some(5), |_| {}).unwrap() {
            BatchRun::Suspended(snap) => snap,
            BatchRun::Complete(_) => panic!("40 job-steps cannot fit in 5 rounds"),
        };
        assert_eq!(snap.len(), 2);
        assert!(snap.iter().all(|j| j.stop.is_none()));
        let resumed = match scheduler.run_session(&specs, Some(&snap), None, |_| {}).unwrap() {
            BatchRun::Complete(outcomes) => outcomes,
            BatchRun::Suspended(_) => panic!("uncapped resume must complete"),
        };
        for (a, b) in reference.iter().zip(&resumed) {
            assert_eq!(a.output.gbest_fit, b.output.gbest_fit, "{}", a.name);
            assert_eq!(a.output.history, b.output.history, "{}", a.name);
            assert_eq!(a.steps, b.steps, "{}", a.name);
            assert_eq!(a.stop, b.stop, "{}", a.name);
        }
    }

    #[test]
    fn session_resume_rejects_mismatched_snapshots() {
        let specs = vec![spec("x", EngineKind::Queue, 32, 6, 1)];
        let scheduler = JobScheduler::with_workers(1);
        let snap = match scheduler.run_session(&specs, None, Some(1), |_| {}).unwrap() {
            BatchRun::Suspended(snap) => snap,
            BatchRun::Complete(_) => panic!("must suspend"),
        };
        // Length mismatch.
        let err = scheduler
            .run_session(&specs, Some(&[]), None, |_| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("0 jobs"), "{err}");
        // Name mismatch.
        let renamed = vec![spec("y", EngineKind::Queue, 32, 6, 1)];
        let err = scheduler
            .run_session(&renamed, Some(&snap), None, |_| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("\"x\""), "{err}");
        // Engine-kind mismatch.
        let rekind = vec![spec("x", EngineKind::Reduction, 32, 6, 1)];
        let err = scheduler
            .run_session(&rekind, Some(&snap), None, |_| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("queue"), "{err}");
        // Fitness mismatch: the swarm state is meaningless under another
        // function — must be a loud error, not a silently-wrong resume.
        let mut refit = spec("x", EngineKind::Queue, 32, 6, 1);
        refit.fitness = Arc::new(crate::fitness::Sphere);
        let err = scheduler
            .run_session(&[refit], Some(&snap), None, |_| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("cubic") && err.contains("sphere"), "{err}");
    }

    #[test]
    fn stop_reason_codes_roundtrip() {
        for reason in [
            StopReason::Exhausted,
            StopReason::TargetReached,
            StopReason::MaxIter,
            StopReason::Stalled,
            StopReason::Cancelled,
        ] {
            assert_eq!(StopReason::from_code(reason.code()).unwrap(), reason);
        }
        assert!(StopReason::from_code(9).is_err());
    }

    #[test]
    fn xla_kinds_are_rejected() {
        let scheduler = JobScheduler::with_workers(1);
        let mut s = spec("x", EngineKind::Queue, 8, 2, 1);
        s.engine = EngineKind::XlaSync;
        let err = scheduler.run(&[s]).unwrap_err().to_string();
        assert!(err.contains("not schedulable"), "{err}");
    }

    #[test]
    fn empty_spec_list_is_fine() {
        let scheduler = JobScheduler::with_workers(1);
        assert!(scheduler.run(&[]).unwrap().is_empty());
    }

    #[test]
    fn duplicate_job_names_are_rejected_at_intake() {
        // Names are identity keys (the service addresses jobs by name):
        // a second "twin" must be a loud error, not a silent shadow.
        let scheduler = JobScheduler::with_workers(1);
        let specs = vec![
            spec("twin", EngineKind::Queue, 32, 5, 1),
            spec("twin", EngineKind::Reduction, 32, 5, 2),
        ];
        let err = scheduler.run(&specs).unwrap_err().to_string();
        assert!(err.contains("twin") && err.contains("unique"), "{err}");
    }

    #[test]
    fn session_admit_cancel_and_recycle_slots() {
        let scheduler = JobScheduler::with_workers(2);
        let mut session = scheduler.session();
        assert_eq!(session.admit(spec("a", EngineKind::Queue, 32, 40, 1)).unwrap(), 0);
        assert_eq!(session.admit(spec("b", EngineKind::Queue, 32, 40, 2)).unwrap(), 1);
        assert_eq!(session.live(), 2);
        for _ in 0..4 {
            session.round(&mut |_| {}).unwrap();
        }
        // Cancel at a round boundary: outcome carries the steps done.
        let out = session.cancel("a").unwrap();
        assert_eq!(out.stop, StopReason::Cancelled);
        assert!(out.steps > 0 && out.steps < 40, "steps {}", out.steps);
        assert_eq!(out.output.iters, out.steps);
        assert_eq!(session.live(), 1);
        // Cancelling again (or an unknown name) is loud.
        assert!(session.cancel("a").is_err());
        assert!(session.cancel("nope").is_err());
        // The freed slot 0 is recycled by the next admission; the name
        // is reusable once the original job is gone.
        assert_eq!(session.admit(spec("a", EngineKind::Queue, 32, 6, 3)).unwrap(), 0);
        while session.live() > 0 {
            session.round(&mut |_| {}).unwrap();
        }
        let mut reaped = Vec::new();
        session.reap(|o| reaped.push(o)).unwrap();
        assert_eq!(reaped.len(), 2);
        assert_eq!(session.occupied(), 0);
        assert_eq!(&*reaped[0].name, "a");
        assert_eq!(reaped[0].steps, 6);
        assert_eq!(&*reaped[1].name, "b");
        assert_eq!(reaped[1].steps, 40);
    }
}
