//! Multi-job scheduler — many concurrent PSO jobs multiplexed over one
//! shared [`GridPool`].
//!
//! The step-wise engine core ([`crate::engine::Run`]) makes a run a
//! resumable object: all buffers live in the `Run`, a `step()` advances
//! one iteration, and nothing about the trajectory depends on *when* the
//! step executes. [`JobScheduler`] exploits exactly that: it prepares one
//! `Run` per [`JobSpec`], then interleaves single steps over the shared
//! worker pool under a [`SchedPolicy`] until every job hits a
//! [`TerminationCriteria`] bound or exhausts its iteration budget.
//!
//! **Concurrent streams.** When the shared pool is built with `S > 1`
//! stream groups ([`crate::exec::GridPool::with_streams`]), the scheduler
//! runs in concurrent mode: each job is pinned to pool stream
//! `job_index % S` at prepare time, and every scheduling round picks up
//! to `S` live jobs — under the same policy, no two sharing a stream —
//! and steps them in parallel. This lifts the paper's Algorithm-3
//! asynchrony idea from intra-run (thread groups vs the barrier) to
//! cross-job (grids vs the launch guard): N tenants no longer serialize
//! on one grid-in-flight. [`JobScheduler::batch_steps`] additionally
//! batches `k` iterations per scheduling round through
//! [`Run::step_many`], amortizing per-step dispatch overhead at the cost
//! of batch-granular telemetry and termination checks (the explicit
//! `max_iter` step cap is still honored exactly — batches are clamped to
//! it).
//!
//! **Persistent executors & the allocation-free steady state.** A
//! concurrent round is stepped by S−1 long-lived per-stream executor
//! threads (`executor`-module docs) that receive `(run, k)` commands
//! over command slots with the pool's spin-then-park discipline — a
//! round is a publish + wake, not a spawn + join, removing the
//! scheduler-level "launch overhead" (`benches/scheduler_latency.rs`
//! measures the difference against the legacy
//! [`JobScheduler::spawn_per_round`] path, which is kept as the
//! baseline). All round bookkeeping lives in buffers allocated once per
//! session, so a warmed-up scheduling round performs **zero heap
//! allocations** when nothing improves and nothing is preempted
//! (`rust/tests/zero_alloc.rs`).
//!
//! **Determinism.** Because a `Run` owns its whole mutable state and a
//! grid launch never spans runs, a job's trajectory is bit-identical
//! whether it runs alone, interleaved on one stream, or concurrently
//! across streams under any policy and batch size — for the bit-exact
//! engines (CPU, Reduction, Loop-Unrolling, Queue). Queue-Lock and
//! Async-Persistent carry their documented intra-run races, but those
//! races are confined to the job's own `Run`: neighbours still cannot
//! perturb each other. `rust/tests/scheduler_determinism.rs` enforces the
//! bit-exact half.
//!
//! **Preemption & migration.** Runs are checkpointable
//! ([`crate::engine::Run::checkpoint`]), which upgrades the scheduler
//! from cooperative interleaving to true preemptive multi-tenancy: with
//! [`JobScheduler::preempt_quantum`] set and more live jobs than
//! streams, a job that has run its quantum is **suspended** to a
//! [`RunCheckpoint`] (its buffers freed), and when the policy next picks
//! it, it is **restored onto whichever stream is free that round** —
//! migration. [`JobScheduler::run_session`] additionally bounds a whole
//! batch to `max_rounds` scheduling rounds and returns a
//! [`BatchRun::Suspended`] snapshot of every job ([`JobCheckpoint`]),
//! which a later session — same process or another one, via the
//! `cupso batch --checkpoint-dir` / `cupso resume` round-trip — resumes.
//! Because restore is bit-exact for the bit-exact engines, *any*
//! suspend/restore/migrate schedule yields bit-identical per-job results
//! (`rust/tests/checkpoint_resume.rs`).
//!
//! This is the ROADMAP's "many concurrent optimization jobs" seam: PSO-PS
//! (arXiv:2009.03816) treats PSO as a long-lived service, and
//! time-critical deployments (arXiv:1401.0546) need early termination and
//! bounded per-step latency — both fall out of step-wise runs plus this
//! scheduler.

mod executor;

use crate::checkpoint::{JobCheckpoint, RunCheckpoint};
use crate::config::{EngineKind, JobConfig};
use crate::engine::{self, ParallelSettings, Run, StepReport};
use crate::exec::GridPool;
use crate::fitness::{by_name, Fitness, Objective};
use crate::pso::{PsoParams, RunOutput};
use anyhow::{bail, Context, Result};
use executor::{spin_budget, StreamExecutors};
use std::sync::Arc;

/// When to stop a job before its `params.max_iter` budget.
///
/// All bounds are optional and combined with OR: the first one hit wins.
/// The run's own iteration budget always applies on top.
#[derive(Debug, Clone, Default)]
pub struct TerminationCriteria {
    /// Hard cap on scheduler steps (iterations) for this job.
    pub max_iter: Option<u64>,
    /// Stop once the global best is at least this good (`>=` under
    /// Maximize, `<=` under Minimize).
    pub target_fit: Option<f64>,
    /// Stop after this many consecutive steps without a global-best
    /// improvement.
    pub stall_window: Option<u64>,
}

impl TerminationCriteria {
    /// No early termination: run to the iteration budget.
    pub fn none() -> Self {
        Self::default()
    }

    /// Builder: cap scheduler steps.
    pub fn with_max_iter(mut self, steps: u64) -> Self {
        self.max_iter = Some(steps);
        self
    }

    /// Builder: stop at a target fitness.
    pub fn with_target_fit(mut self, fit: f64) -> Self {
        self.target_fit = Some(fit);
        self
    }

    /// Builder: stop after a stall.
    pub fn with_stall_window(mut self, steps: u64) -> Self {
        self.stall_window = Some(steps);
        self
    }

    /// Evaluate the criteria after a step. `steps` counts executed steps,
    /// `stalled` counts consecutive non-improving steps, `gbest` is the
    /// job's current best under `objective`.
    pub fn check(
        &self,
        objective: Objective,
        gbest: f64,
        steps: u64,
        stalled: u64,
    ) -> Option<StopReason> {
        if let Some(target) = self.target_fit {
            // Reached when the target is not strictly better than gbest.
            if !objective.better(target, gbest) {
                return Some(StopReason::TargetReached);
            }
        }
        if let Some(cap) = self.max_iter {
            if steps >= cap {
                return Some(StopReason::MaxIter);
            }
        }
        if let Some(window) = self.stall_window {
            if stalled >= window {
                return Some(StopReason::Stalled);
            }
        }
        None
    }
}

/// Why a job stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The run's own `params.max_iter` budget is spent.
    Exhausted,
    /// [`TerminationCriteria::target_fit`] reached.
    TargetReached,
    /// [`TerminationCriteria::max_iter`] cap hit.
    MaxIter,
    /// [`TerminationCriteria::stall_window`] consecutive stale steps.
    Stalled,
}

impl StopReason {
    /// Stable wire code for [`JobCheckpoint::stop`] (version-1 format —
    /// never renumber).
    pub fn code(self) -> u8 {
        match self {
            StopReason::Exhausted => 0,
            StopReason::TargetReached => 1,
            StopReason::MaxIter => 2,
            StopReason::Stalled => 3,
        }
    }

    /// Inverse of [`code`](Self::code).
    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => StopReason::Exhausted,
            1 => StopReason::TargetReached,
            2 => StopReason::MaxIter,
            3 => StopReason::Stalled,
            other => bail!("unknown stop-reason code {other}"),
        })
    }
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            StopReason::Exhausted => "exhausted",
            StopReason::TargetReached => "target-reached",
            StopReason::MaxIter => "max-iter",
            StopReason::Stalled => "stalled",
        };
        f.write_str(s)
    }
}

/// One tenant job: engine kind, workload, seed, and stop bounds.
pub struct JobSpec {
    /// Display name (batch-config section name). Interned (`Arc<str>`) so
    /// telemetry, outcomes and checkpoint snapshots share one allocation
    /// instead of cloning the string per round/persist.
    pub name: Arc<str>,
    /// Plane-A engine kind driving this job.
    pub engine: EngineKind,
    /// The workload.
    pub params: PsoParams,
    /// Fitness function (shared, engines borrow it per step).
    pub fitness: Arc<dyn Fitness + Send>,
    /// Optimization sense.
    pub objective: Objective,
    /// Master seed.
    pub seed: u64,
    /// Early-termination bounds.
    pub termination: TerminationCriteria,
    /// Step budget this job would like to finish within — consumed by
    /// [`SchedPolicy::EarliestDeadlineFirst`]; ignored by round-robin.
    pub deadline: Option<u64>,
}

impl JobSpec {
    /// A job with default objective/termination (run to budget).
    pub fn new(
        name: &str,
        engine: EngineKind,
        params: PsoParams,
        fitness: Arc<dyn Fitness + Send>,
        objective: Objective,
        seed: u64,
    ) -> Self {
        Self {
            name: Arc::from(name),
            engine,
            params,
            fitness,
            objective,
            seed,
            termination: TerminationCriteria::none(),
            deadline: None,
        }
    }

    /// Build a spec from a batch-config job entry.
    pub fn from_config(cfg: &JobConfig) -> Result<Self> {
        let fitness = by_name(&cfg.fitness)
            .with_context(|| format!("job {}: unknown fitness {}", cfg.name, cfg.fitness))?;
        if !cfg.engine.is_plane_a() {
            bail!(
                "job {}: engine {} is not schedulable (Plane-A only)",
                cfg.name,
                cfg.engine
            );
        }
        let objective = cfg.objective.unwrap_or(fitness.default_objective());
        let params = PsoParams::for_fitness(
            fitness.as_ref(),
            cfg.particles,
            cfg.dim,
            cfg.iters,
            cfg.vmax_frac,
        );
        Ok(Self {
            name: cfg.name.as_str().into(),
            engine: cfg.engine,
            params,
            fitness: Arc::from(fitness),
            objective,
            seed: cfg.seed,
            termination: TerminationCriteria {
                max_iter: cfg.max_steps,
                target_fit: cfg.target_fitness,
                stall_window: cfg.stall_window,
            },
            deadline: cfg.deadline,
        })
    }
}

/// Which live job gets the next step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Fair progress: schedule the least-progressed live jobs first
    /// (ties → lowest index). With a single stream this is exactly the
    /// classic cycle-through-live-jobs-one-step-each order; with S
    /// streams it fills every round with up to S jobs while keeping the
    /// jobs of a contended stream within one round of each other.
    #[default]
    RoundRobin,
    /// Greedy EDF: always step the live job with the smallest remaining
    /// deadline slack (`deadline - steps_done`; jobs without a deadline
    /// rank last). Ties break on job index, so scheduling is fully
    /// deterministic.
    EarliestDeadlineFirst,
}

impl SchedPolicy {
    /// Parse CLI/config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "roundrobin" | "rr" => Some(Self::RoundRobin),
            "edf" | "deadline" | "earliestdeadlinefirst" => Some(Self::EarliestDeadlineFirst),
            _ => None,
        }
    }
}

impl std::fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedPolicy::RoundRobin => f.write_str("round-robin"),
            SchedPolicy::EarliestDeadlineFirst => f.write_str("edf"),
        }
    }
}

/// Telemetry for one scheduling round of one job (with `batch_steps = 1`,
/// one report per executed step).
#[derive(Debug, Clone)]
pub struct JobReport<'a> {
    /// Index of the job in the spec slice.
    pub job: usize,
    /// Job name.
    pub name: &'a str,
    /// Steps (iterations) the job has executed, this round included.
    pub iter: u64,
    /// The job's global-best fitness after the round.
    pub gbest_fit: f64,
    /// Whether any step of the round improved the job's global best.
    pub improved: bool,
    /// Set on the job's final round.
    pub finished: Option<StopReason>,
}

/// Final result of one scheduled job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Job name (shared with the spec's interned name).
    pub name: Arc<str>,
    /// Engine kind that ran it.
    pub engine: EngineKind,
    /// Why it stopped.
    pub stop: StopReason,
    /// Steps (iterations) executed.
    pub steps: u64,
    /// The run's output — for the bit-exact engines, identical to the
    /// same job run solo.
    pub output: RunOutput,
}

/// Result of one scheduling session ([`JobScheduler::run_session`]).
pub enum BatchRun {
    /// Every job terminated; outcomes in spec order.
    Complete(Vec<JobOutcome>),
    /// The round cap fired first: one [`JobCheckpoint`] per job (spec
    /// order, finished jobs included with their stop reason), ready to be
    /// persisted and resumed — in this process or another.
    Suspended(Vec<JobCheckpoint>),
}

/// Multiplexes N concurrent jobs over one shared [`GridPool`].
pub struct JobScheduler {
    settings: ParallelSettings,
    policy: SchedPolicy,
    batch_steps: u64,
    /// Preemption quantum in steps (`None` = cooperative scheduling).
    preempt_quantum: Option<u64>,
    /// Step concurrent rounds with per-round scoped threads instead of
    /// the persistent executors (the legacy baseline; see
    /// [`JobScheduler::spawn_per_round`]).
    spawn_per_round: bool,
}

struct LiveJob<'a> {
    /// The live run — `None` while the job is suspended to `parked`.
    run: Option<Box<dyn Run + 'a>>,
    /// The suspension checkpoint of an inactive job (shared, so snapshot
    /// persistence never deep-copies a parked swarm).
    parked: Option<Arc<RunCheckpoint>>,
    steps: u64,
    stalled: u64,
    stop: Option<StopReason>,
    deadline: Option<u64>,
    /// Pool stream the job's launches are currently pinned to. A
    /// suspended job loses its pinning and may be restored onto any free
    /// stream (migration).
    stream: usize,
    /// Steps executed since the last (re)activation — the preemption
    /// quantum counts against this, not lifetime steps.
    active_steps: u64,
}

impl JobScheduler {
    /// Scheduler over the given pool/geometry (round-robin by default,
    /// one step per scheduling round). A multi-stream pool enables the
    /// concurrent mode (see module docs).
    pub fn new(settings: ParallelSettings) -> Self {
        Self {
            settings,
            policy: SchedPolicy::RoundRobin,
            batch_steps: 1,
            preempt_quantum: None,
            spawn_per_round: false,
        }
    }

    /// Scheduler on a fresh single-stream pool with `workers` threads
    /// (0 = all cores).
    pub fn with_workers(workers: usize) -> Self {
        Self::new(ParallelSettings::with_workers(workers))
    }

    /// Scheduler on a fresh pool with `workers` threads (0 = all cores)
    /// split into `streams` concurrent stream groups.
    pub fn with_streams(workers: usize, streams: usize) -> Self {
        Self::new(ParallelSettings::with_streams(workers, streams))
    }

    /// Override the stepping policy.
    pub fn policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Step each picked job `k` iterations per scheduling round (clamps
    /// to ≥ 1). Batching amortizes per-step dispatch overhead; telemetry,
    /// target-fitness and stall checks become batch-granular, while an
    /// explicit `max_iter` step cap is still honored exactly.
    pub fn batch_steps(mut self, k: u64) -> Self {
        self.batch_steps = k.max(1);
        self
    }

    /// Enable preemptive scheduling: when live jobs outnumber streams, a
    /// job that has executed `quantum` steps since its last activation is
    /// suspended to a checkpoint after its round, freeing its buffers;
    /// the policy later restores it onto whichever stream is free
    /// (migration). `0` disables preemption (the default, cooperative
    /// mode). Bit-exact engines produce bit-identical results under any
    /// quantum — preemption only changes *where and when* work happens.
    pub fn preempt_quantum(mut self, quantum: u64) -> Self {
        self.preempt_quantum = (quantum > 0).then_some(quantum);
        self
    }

    /// Step concurrent rounds by spawning one scoped OS thread per extra
    /// job per round (the pre-executor behavior) instead of publishing to
    /// the persistent stream executors. The two paths are bit-identical
    /// for every engine (`rust/tests/scheduler_determinism.rs`); this
    /// knob exists so `benches/scheduler_latency.rs` can measure the
    /// per-round fixed cost the executors remove. Off by default.
    pub fn spawn_per_round(mut self, enabled: bool) -> Self {
        self.spawn_per_round = enabled;
        self
    }

    /// The shared pool jobs are multiplexed over.
    pub fn pool(&self) -> &Arc<GridPool> {
        &self.settings.pool
    }

    /// Jobs stepped in parallel per scheduling round (the pool's stream
    /// count).
    pub fn streams(&self) -> usize {
        self.settings.pool.streams()
    }

    /// Run all jobs to termination, discarding telemetry.
    pub fn run(&self, specs: &[JobSpec]) -> Result<Vec<JobOutcome>> {
        self.run_with(specs, |_| {})
    }

    /// Run all jobs to termination, streaming a [`JobReport`] per
    /// scheduling round and job (= per step when `batch_steps` is 1).
    ///
    /// Outcomes are returned in spec order regardless of completion
    /// order. In concurrent mode (multi-stream pool) the reports of one
    /// round are delivered in job-index order after the whole round
    /// joined, so the telemetry stream stays deterministic.
    pub fn run_with<F: FnMut(&JobReport<'_>)>(
        &self,
        specs: &[JobSpec],
        telemetry: F,
    ) -> Result<Vec<JobOutcome>> {
        match self.run_session(specs, None, None, telemetry)? {
            BatchRun::Complete(outcomes) => Ok(outcomes),
            BatchRun::Suspended(_) => unreachable!("an uncapped session cannot suspend"),
        }
    }

    /// The general scheduling entry: run at most `max_rounds` scheduling
    /// rounds (`None` = to termination), optionally continuing from a
    /// prior session's `resume` snapshot (one [`JobCheckpoint`] per spec,
    /// same order and names).
    ///
    /// Resumed jobs start suspended and are restored lazily when the
    /// policy first picks them — onto whichever stream is free that
    /// round, which may differ from their pre-suspension pinning
    /// (migration; also across *sessions* the stream layout may change
    /// entirely, e.g. a different `streams` count). For the bit-exact
    /// engines none of this is observable in the results.
    pub fn run_session<F: FnMut(&JobReport<'_>)>(
        &self,
        specs: &[JobSpec],
        resume: Option<&[JobCheckpoint]>,
        max_rounds: Option<u64>,
        telemetry: F,
    ) -> Result<BatchRun> {
        self.run_session_with(specs, resume, max_rounds, None, |_| Ok(()), telemetry)
    }

    /// [`run_session`](Self::run_session) plus an **in-place periodic
    /// persistence hook**: every `persist_every` rounds the session hands
    /// a full batch snapshot (same shape as [`BatchRun::Suspended`]) to
    /// `persist` and *keeps running* — the run buffers stay live, nothing
    /// is suspended or reallocated, and the relaxed engines'
    /// interleavings are not perturbed. This is what
    /// `cupso batch --checkpoint-dir --checkpoint-every` uses; the old
    /// behavior (suspend the whole batch per period, then resume it) paid
    /// a full teardown + restore per checkpoint.
    ///
    /// A `persist` error aborts the session (the batch state is lost to
    /// this process but the last persisted snapshot survives on disk).
    pub fn run_session_with<F, P>(
        &self,
        specs: &[JobSpec],
        resume: Option<&[JobCheckpoint]>,
        max_rounds: Option<u64>,
        persist_every: Option<u64>,
        mut persist: P,
        mut telemetry: F,
    ) -> Result<BatchRun>
    where
        F: FnMut(&JobReport<'_>),
        P: FnMut(&[JobCheckpoint]) -> Result<()>,
    {
        let streams = self.settings.pool.streams();
        let mut live: Vec<LiveJob<'_>> = Vec::with_capacity(specs.len());
        let mut finished = 0usize;
        match resume {
            None => {
                // Fresh batch: prepare every run up front — all allocation
                // happens here, steps stay allocation-free on the hot
                // path. Each job starts pinned to pool stream `i % S`.
                for (i, spec) in specs.iter().enumerate() {
                    let mut engine =
                        engine::build_with(spec.engine, self.settings.clone().on_stream(i))
                            .with_context(|| {
                                format!(
                                    "job {}: engine {} is not schedulable",
                                    spec.name, spec.engine
                                )
                            })?;
                    let fitness: &dyn Fitness = &*spec.fitness;
                    live.push(LiveJob {
                        run: Some(engine.prepare(&spec.params, fitness, spec.objective, spec.seed)),
                        parked: None,
                        steps: 0,
                        stalled: 0,
                        stop: None,
                        deadline: spec.deadline,
                        stream: i % streams,
                        active_steps: 0,
                    });
                }
            }
            Some(ckpts) => {
                if ckpts.len() != specs.len() {
                    bail!(
                        "resume snapshot has {} jobs, specs have {}",
                        ckpts.len(),
                        specs.len()
                    );
                }
                for (i, (spec, ckpt)) in specs.iter().zip(ckpts).enumerate() {
                    if ckpt.name != spec.name {
                        bail!(
                            "resume snapshot job {i} is {:?}, spec says {:?}",
                            ckpt.name,
                            spec.name
                        );
                    }
                    ckpt.run
                        .validate()
                        .with_context(|| format!("resuming job {}", spec.name))?;
                    if crate::checkpoint::RunKind::from_engine(spec.engine) != Some(ckpt.run.kind) {
                        bail!(
                            "resuming job {}: checkpoint is a {} run, spec wants engine {}",
                            spec.name,
                            ckpt.run.kind,
                            spec.engine
                        );
                    }
                    // The swarm's fit/pbest arrays were computed under the
                    // recorded fitness — continuing under a different one
                    // would be silently wrong, never do it.
                    if ckpt.fitness != spec.fitness.name() {
                        bail!(
                            "resuming job {}: checkpoint was taken under fitness {:?}, spec uses {:?}",
                            spec.name,
                            ckpt.fitness,
                            spec.fitness.name()
                        );
                    }
                    let stop = ckpt.stop.map(StopReason::from_code).transpose()?;
                    if stop.is_some() {
                        finished += 1;
                    }
                    // Arc clone: resuming shares the caller's checkpoint
                    // instead of deep-copying the swarm arrays.
                    live.push(LiveJob {
                        run: None,
                        parked: Some(Arc::clone(&ckpt.run)),
                        steps: ckpt.run.iter,
                        stalled: ckpt.stalled,
                        stop,
                        deadline: spec.deadline,
                        stream: i % streams,
                        active_steps: 0,
                    });
                }
            }
        }

        // Round state and executors are allocated once per session: the
        // steady-state loop below is allocation-free per round
        // (rust/tests/zero_alloc.rs pins this for the bit-exact engines).
        let mut rs = RoundState::new(streams, live.len());
        let executors = (!self.spawn_per_round && streams > 1 && live.len() > 1).then(|| {
            let count = streams.min(live.len()) - 1;
            let total = self.settings.pool.workers() + streams + count;
            StreamExecutors::new(count, spin_budget(total))
        });

        let mut rounds = 0u64;
        while finished < live.len() {
            if max_rounds.is_some_and(|cap| rounds >= cap) {
                return Ok(BatchRun::Suspended(snapshot(specs, &live)));
            }
            rounds += 1;
            match self.policy {
                SchedPolicy::RoundRobin => pick_round_robin(&live, streams, &mut rs),
                SchedPolicy::EarliestDeadlineFirst => pick_edf(&live, streams, &mut rs),
            };
            debug_assert!(!rs.picked.is_empty(), "unfinished job exists");
            self.step_round(&mut live, specs, executors.as_ref(), &mut rs)?;
            for (idx, report) in rs.reports.iter() {
                let idx = *idx;
                let job = &mut live[idx];
                let spec = &specs[idx];
                let executed = report.iter - job.steps;
                job.steps = report.iter;
                job.active_steps += executed;
                if report.improved {
                    job.stalled = 0;
                } else {
                    job.stalled += executed;
                }
                // Criteria outrank budget exhaustion so a target hit on the
                // final iteration still reports TargetReached (matching the
                // precedence TerminationCriteria::check documents).
                let stop = spec
                    .termination
                    .check(spec.objective, report.gbest_fit, job.steps, job.stalled)
                    .or(report.done.then_some(StopReason::Exhausted));
                telemetry(&JobReport {
                    job: idx,
                    name: &spec.name,
                    iter: job.steps,
                    gbest_fit: report.gbest_fit,
                    improved: report.improved,
                    finished: stop,
                });
                if stop.is_some() {
                    job.stop = stop;
                    finished += 1;
                }
            }
            // Preemption: once a picked job has spent its quantum and the
            // live set still outnumbers the streams, suspend it — its
            // buffers are MOVED into a checkpoint (no deep copy) and its
            // stream frees up for a neighbour next round.
            if let Some(quantum) = self.preempt_quantum {
                let unfinished = live.iter().filter(|j| j.stop.is_none()).count();
                if unfinished > streams {
                    for &(idx, _) in &rs.picked {
                        let job = &mut live[idx];
                        if job.stop.is_none() && job.active_steps >= quantum {
                            if let Some(run) = job.run.take() {
                                job.parked = Some(Arc::new(run.into_checkpoint()));
                            }
                        }
                    }
                }
            }
            // Skip the hook when the next iteration will suspend anyway:
            // the suspension snapshot captures the identical state, and a
            // back-to-back duplicate would waste a retention slot.
            let suspending_next = max_rounds.is_some_and(|cap| rounds >= cap);
            if persist_every.is_some_and(|n| rounds % n == 0)
                && finished < live.len()
                && !suspending_next
            {
                persist(&snapshot(specs, &live))?;
            }
        }

        let mut outcomes = Vec::with_capacity(live.len());
        for (i, (job, spec)) in live.into_iter().zip(specs).enumerate() {
            let run = match job.run {
                Some(run) => run,
                None => {
                    // Job finished in a *previous* session (or was never
                    // reactivated): restore once, just to finish.
                    let ckpt = job.parked.expect("inactive job holds its checkpoint");
                    let fitness: &dyn Fitness = &*spec.fitness;
                    engine::restore_with(&ckpt, self.settings.clone().on_stream(i), fitness)
                        .with_context(|| format!("finishing job {}", spec.name))?
                }
            };
            outcomes.push(JobOutcome {
                name: spec.name.clone(),
                engine: spec.engine,
                stop: job.stop.expect("every job terminated"),
                steps: job.steps,
                output: run.finish(),
            });
        }
        Ok(BatchRun::Complete(outcomes))
    }

    /// Step every picked job once (a batch of `batch_steps` iterations),
    /// in parallel when the round holds several jobs — each job's
    /// launches go to its assigned pool stream, so the grids genuinely
    /// overlap. Suspended picks are restored first, onto the stream the
    /// round assigned them (migration when it differs from their last
    /// pinning). Leaves `(index, report)` pairs sorted by job index in
    /// `rs.reports`.
    ///
    /// Concurrent rounds default to the persistent executors (publish +
    /// wake per extra job); `executors` is `None` in spawn-per-round mode,
    /// which falls back to one scoped OS thread per extra job — the
    /// legacy baseline `benches/scheduler_latency.rs` measures against.
    fn step_round(
        &self,
        live: &mut [LiveJob<'_>],
        specs: &[JobSpec],
        executors: Option<&StreamExecutors>,
        rs: &mut RoundState,
    ) -> Result<()> {
        for &(idx, stream) in &rs.picked {
            if live[idx].run.is_none() {
                let ckpt = live[idx].parked.take().expect("parked job has a checkpoint");
                let fitness: &dyn Fitness = &*specs[idx].fitness;
                let run =
                    engine::restore_with(&ckpt, self.settings.clone().on_stream(stream), fitness)
                        .with_context(|| format!("restoring job {}", specs[idx].name))?;
                live[idx].run = Some(run);
                live[idx].stream = stream;
                live[idx].active_steps = 0;
            }
        }
        rs.reports.clear();
        if let [(idx, _)] = *rs.picked {
            // Serialized fast path (always taken on a single-stream
            // pool): no stepping threads, identical to the pre-stream
            // scheduler loop.
            let k = effective_batch(self.batch_steps, &specs[idx].termination, live[idx].steps);
            let run = live[idx].run.as_mut().expect("picked job is active");
            rs.reports.push((idx, run.step_many(k)));
            return Ok(());
        }
        if let Some(execs) = executors {
            // Persistent-executor path: publish every pick but the first
            // to an executor slot, step the first inline on the
            // scheduling thread, then collect the echoes — no spawn, no
            // join, no allocation.
            rs.inflight.clear();
            let mut first: Option<(usize, u64, &mut Box<dyn Run + '_>)> = None;
            for (i, job) in live.iter_mut().enumerate() {
                if !rs.picked.iter().any(|&(p, _)| p == i) {
                    continue;
                }
                let k = effective_batch(self.batch_steps, &specs[i].termination, job.steps);
                let run = job.run.as_mut().expect("picked job is active");
                if first.is_none() {
                    first = Some((i, k, run));
                } else {
                    let e = rs.inflight.len();
                    // SAFETY: every submitted slot is waited on below,
                    // before the runs are touched again and before this
                    // function returns; each run goes to one slot.
                    unsafe { execs.submit(e, &mut **run, k) };
                    rs.inflight.push(i);
                }
            }
            let (i0, k0, run0) = first.expect("non-empty round");
            rs.reports.push((i0, run0.step_many(k0)));
            for (e, &i) in rs.inflight.iter().enumerate() {
                execs.wait(e);
                rs.reports.push((i, execs.take_report(e)));
            }
        } else {
            // Legacy spawn-per-round path: S − 1 scoped threads per round.
            let tasks: Vec<(usize, u64, &mut LiveJob<'_>)> = live
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| rs.picked.iter().any(|&(p, _)| p == *i))
                .map(|(i, job)| {
                    let k = effective_batch(self.batch_steps, &specs[i].termination, job.steps);
                    (i, k, job)
                })
                .collect();
            let stepped = std::thread::scope(|scope| {
                let mut it = tasks.into_iter();
                let (i0, k0, job0) = it.next().expect("non-empty round");
                let handles: Vec<_> = it
                    .map(|(i, k, job)| {
                        scope.spawn(move || {
                            let run = job.run.as_mut().expect("picked job is active");
                            (i, run.step_many(k))
                        })
                    })
                    .collect();
                // The scheduling thread steps the first job itself: a
                // round of S jobs costs S − 1 spawns.
                let run0 = job0.run.as_mut().expect("picked job is active");
                let mut out = vec![(i0, run0.step_many(k0))];
                for h in handles {
                    out.push(h.join().expect("stepping thread panicked"));
                }
                out
            });
            rs.reports.extend(stepped);
        }
        rs.reports.sort_unstable_by_key(|&(i, _)| i);
        Ok(())
    }
}

/// Reusable per-session scheduling buffers, allocated once so the
/// steady-state loop performs zero heap allocations per round.
struct RoundState {
    /// Policy-ordering scratch (live job indices).
    order: Vec<usize>,
    /// Streams taken this round.
    used: Vec<bool>,
    /// The round's picks: `(job index, stream)`.
    picked: Vec<(usize, usize)>,
    /// Job index per submitted executor slot, in submission order.
    inflight: Vec<usize>,
    /// The round's step reports, sorted by job index before delivery.
    reports: Vec<(usize, StepReport)>,
}

impl RoundState {
    fn new(streams: usize, jobs: usize) -> Self {
        let width = streams.min(jobs.max(1));
        Self {
            order: Vec::with_capacity(jobs),
            used: vec![false; streams],
            picked: Vec::with_capacity(width),
            inflight: Vec::with_capacity(width),
            reports: Vec::with_capacity(width),
        }
    }
}

/// One [`JobCheckpoint`] per job, in spec order — active jobs checkpoint
/// their live runs (a copy is unavoidable: the run keeps stepping), while
/// suspended jobs share their parked checkpoint via `Arc` instead of
/// deep-copying it.
fn snapshot(specs: &[JobSpec], live: &[LiveJob<'_>]) -> Vec<JobCheckpoint> {
    live.iter()
        .zip(specs)
        .map(|(job, spec)| JobCheckpoint {
            name: spec.name.clone(),
            fitness: spec.fitness.name().to_string(),
            stalled: job.stalled,
            stop: job.stop.map(StopReason::code),
            target_fit: spec.termination.target_fit,
            stall_window: spec.termination.stall_window,
            max_steps: spec.termination.max_iter,
            deadline: spec.deadline,
            run: match &job.run {
                Some(run) => Arc::new(run.checkpoint()),
                None => Arc::clone(job.parked.as_ref().expect("inactive job holds its checkpoint")),
            },
        })
        .collect()
}

/// Batch size for one job's next round: the configured batch, clamped so
/// an explicit `max_iter` step cap is never overshot (the run's own
/// budget self-limits inside `step_many`).
fn effective_batch(batch: u64, termination: &TerminationCriteria, steps_done: u64) -> u64 {
    match termination.max_iter {
        Some(cap) => batch.min(cap.saturating_sub(steps_done)).max(1),
        None => batch,
    }
}

/// Up to `streams` live jobs, least-progressed first (ties → lowest
/// index), no two sharing a pool stream. This is the fair-share
/// generalization of one-step-each cycling to concurrent rounds: with a
/// single stream it degenerates to exactly the classic cyclic order (all
/// live jobs stay within one step of each other, and the least-stepped
/// lowest index is the next cyclic pick), while under stream conflicts
/// the lagging job of a contended stream always outranks its
/// stream-mates, so nobody starves.
fn pick_round_robin(live: &[LiveJob<'_>], streams: usize, rs: &mut RoundState) {
    rs.order.clear();
    rs.order
        .extend((0..live.len()).filter(|&i| live[i].stop.is_none()));
    rs.order.sort_unstable_by_key(|&i| (live[i].steps, i));
    assign_streams(live, streams, rs);
}

/// Up to `streams` live jobs by ascending deadline slack (`deadline -
/// steps`; jobs without a deadline rank last, ties break on job index so
/// scheduling is fully deterministic), no two sharing a pool stream.
fn pick_edf(live: &[LiveJob<'_>], streams: usize, rs: &mut RoundState) {
    rs.order.clear();
    rs.order
        .extend((0..live.len()).filter(|&i| live[i].stop.is_none()));
    rs.order.sort_unstable_by_key(|&i| {
        let slack = live[i]
            .deadline
            .map(|d| d.saturating_sub(live[i].steps))
            .unwrap_or(u64::MAX);
        (slack, i)
    });
    assign_streams(live, streams, rs);
}

/// Greedily assign the policy-ordered jobs (`rs.order`) to
/// pairwise-distinct streams, into `rs.picked` (one grid in flight per
/// stream per round). An active job keeps its pinning — its buffers
/// already target that stream — and is skipped if the stream is taken
/// this round; a suspended job has no pinning and takes the lowest free
/// stream (that restore-time re-pinning is the migration path). Fully
/// deterministic, and allocation-free: every buffer lives in
/// [`RoundState`].
fn assign_streams(live: &[LiveJob<'_>], streams: usize, rs: &mut RoundState) {
    rs.used.iter_mut().for_each(|u| *u = false);
    rs.picked.clear();
    for &i in &rs.order {
        let stream = if live[i].run.is_some() {
            let s = live[i].stream;
            if rs.used[s] {
                continue;
            }
            s
        } else {
            match rs.used.iter().position(|&u| !u) {
                Some(s) => s,
                None => break,
            }
        };
        rs.used[stream] = true;
        rs.picked.push((i, stream));
        if rs.picked.len() == streams {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    fn spec(name: &str, engine: EngineKind, n: usize, iters: u64, seed: u64) -> JobSpec {
        JobSpec::new(
            name,
            engine,
            PsoParams::paper_1d(n, iters),
            Arc::new(Cubic),
            Objective::Maximize,
            seed,
        )
    }

    #[test]
    fn criteria_target_fit_respects_objective() {
        let c = TerminationCriteria::none().with_target_fit(10.0);
        let max = Objective::Maximize;
        let min = Objective::Minimize;
        assert_eq!(c.check(max, 9.0, 1, 0), None);
        assert_eq!(c.check(max, 10.0, 1, 0), Some(StopReason::TargetReached));
        assert_eq!(c.check(max, 11.0, 1, 0), Some(StopReason::TargetReached));
        assert_eq!(c.check(min, 11.0, 1, 0), None);
        assert_eq!(c.check(min, 9.0, 1, 0), Some(StopReason::TargetReached));
    }

    #[test]
    fn criteria_max_iter_and_stall() {
        let c = TerminationCriteria::none()
            .with_max_iter(5)
            .with_stall_window(3);
        let max = Objective::Maximize;
        assert_eq!(c.check(max, 0.0, 4, 0), None);
        assert_eq!(c.check(max, 0.0, 5, 0), Some(StopReason::MaxIter));
        assert_eq!(c.check(max, 0.0, 2, 3), Some(StopReason::Stalled));
        // Target outranks the caps when several bounds trip at once.
        let c = c.with_target_fit(f64::NEG_INFINITY);
        assert_eq!(c.check(max, 0.0, 5, 3), Some(StopReason::TargetReached));
    }

    #[test]
    fn policies_parse_and_display() {
        assert_eq!(SchedPolicy::parse("round-robin"), Some(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::parse("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(
            SchedPolicy::parse("EDF"),
            Some(SchedPolicy::EarliestDeadlineFirst)
        );
        assert_eq!(SchedPolicy::parse("fifo"), None);
        assert_eq!(SchedPolicy::RoundRobin.to_string(), "round-robin");
    }

    #[test]
    fn round_robin_interleaves_fairly() {
        let scheduler = JobScheduler::with_workers(2);
        let specs = vec![
            spec("a", EngineKind::Queue, 64, 10, 1),
            spec("b", EngineKind::Queue, 64, 10, 2),
        ];
        let mut order = Vec::new();
        let outcomes = scheduler
            .run_with(&specs, |r| order.push(r.job))
            .unwrap();
        // Strict alternation: a b a b …
        for (k, &j) in order.iter().enumerate() {
            assert_eq!(j, k % 2, "step {k} went to job {j}");
        }
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert_eq!(o.steps, 10);
            assert_eq!(o.stop, StopReason::Exhausted);
            assert_eq!(o.output.iters, 10);
        }
    }

    #[test]
    fn edf_runs_tight_deadlines_first() {
        let scheduler = JobScheduler::with_workers(2).policy(SchedPolicy::EarliestDeadlineFirst);
        let mut a = spec("loose", EngineKind::Queue, 64, 8, 1);
        a.deadline = Some(100);
        let mut b = spec("tight", EngineKind::Queue, 64, 8, 2);
        b.deadline = Some(8);
        let specs = vec![a, b];
        let mut finish_order = Vec::new();
        scheduler
            .run_with(&specs, |r| {
                if r.finished.is_some() {
                    finish_order.push(r.job);
                }
            })
            .unwrap();
        assert_eq!(finish_order, vec![1, 0], "tight deadline must finish first");
    }

    #[test]
    fn from_config_respects_vmax_frac() {
        // Regression: vmax_frac used to be hard-coded to 0.5, silently
        // ignoring the batch TOML. A non-default value must change both
        // the derived velocity clamp and the resulting trajectory.
        let mk = |vmax_frac: f64, name: &str| JobConfig {
            name: name.to_string(),
            fitness: "sphere".into(),
            objective: None,
            particles: 64,
            dim: 3,
            iters: 25,
            engine: EngineKind::Queue,
            vmax_frac,
            seed: 7,
            target_fitness: None,
            stall_window: None,
            max_steps: None,
            deadline: None,
        };
        let tight = JobSpec::from_config(&mk(0.05, "tight")).unwrap();
        let wide = JobSpec::from_config(&mk(0.5, "wide")).unwrap();
        // Sphere domain is [-100, 100] → range 200.
        assert_eq!(tight.params.max_v, 10.0);
        assert_eq!(wide.params.max_v, 100.0);
        let scheduler = JobScheduler::with_workers(2);
        let outs = scheduler.run(&[tight, wide]).unwrap();
        assert_ne!(
            outs[0].output.history, outs[1].output.history,
            "vmax_frac did not reach the trajectory"
        );
    }

    #[test]
    fn concurrent_streams_complete_all_jobs() {
        // Smoke for the concurrent mode: more jobs than streams, mixed
        // shapes, both policies — everything must terminate correctly.
        for policy in [SchedPolicy::RoundRobin, SchedPolicy::EarliestDeadlineFirst] {
            let scheduler = JobScheduler::with_streams(2, 3).policy(policy);
            assert_eq!(scheduler.streams(), 3);
            let specs: Vec<JobSpec> = (0..7)
                .map(|j| spec(&format!("j{j}"), EngineKind::Queue, 64, 5 + j as u64, j as u64))
                .collect();
            let outcomes = scheduler.run(&specs).unwrap();
            for (j, o) in outcomes.iter().enumerate() {
                assert_eq!(o.stop, StopReason::Exhausted, "{policy} {}", o.name);
                assert_eq!(o.steps, 5 + j as u64, "{policy} {}", o.name);
                assert_eq!(o.output.iters, o.steps);
            }
        }
    }

    #[test]
    fn batch_steps_amortize_but_honor_the_step_cap() {
        // batch = 8 over a 20-iteration budget: three rounds, exact total.
        let scheduler = JobScheduler::with_workers(2).batch_steps(8);
        let specs = vec![spec("batched", EngineKind::Queue, 64, 20, 1)];
        let mut rounds = Vec::new();
        let outcomes = scheduler
            .run_with(&specs, |r| rounds.push(r.iter))
            .unwrap();
        assert_eq!(rounds, vec![8, 16, 20], "batch boundaries");
        assert_eq!(outcomes[0].steps, 20);
        assert_eq!(outcomes[0].output.iters, 20);
        // An explicit max_iter criterion is clamped to exactly, even
        // mid-batch.
        let mut capped = spec("capped", EngineKind::Queue, 64, 100, 2);
        capped.termination = TerminationCriteria::none().with_max_iter(11);
        let outcomes = JobScheduler::with_workers(2)
            .batch_steps(8)
            .run(&[capped])
            .unwrap();
        assert_eq!(outcomes[0].stop, StopReason::MaxIter);
        assert_eq!(outcomes[0].steps, 11);
        assert_eq!(outcomes[0].output.iters, 11);
    }

    #[test]
    fn round_robin_with_streams_is_fair_within_a_contended_stream() {
        // 3 jobs on 2 streams: jobs 0 and 2 share stream 0, so a round
        // can schedule at most one of them. Least-progressed-first must
        // keep the stream-mates within one step of each other for the
        // whole run (job 1, alone on stream 1, legitimately runs every
        // round).
        let scheduler = JobScheduler::with_streams(2, 2);
        let specs: Vec<JobSpec> = (0..3)
            .map(|j| spec(&format!("j{j}"), EngineKind::Queue, 64, 12, j as u64))
            .collect();
        let mut steps = [0i64; 3];
        let outcomes = scheduler
            .run_with(&specs, |r| {
                steps[r.job] += 1;
                assert!(
                    (steps[0] - steps[2]).abs() <= 1,
                    "stream-0 mates drifted: {steps:?}"
                );
            })
            .unwrap();
        for o in &outcomes {
            assert_eq!(o.steps, 12);
        }
    }

    #[test]
    fn preemptive_scheduling_matches_cooperative() {
        // Any quantum, jobs > streams: bit-exact engines must produce the
        // exact cooperative results despite suspend/restore churn.
        let mk = || {
            vec![
                spec("a", EngineKind::Queue, 64, 15, 1),
                spec("b", EngineKind::Queue, 64, 15, 2),
                spec("c", EngineKind::Reduction, 100, 12, 3),
            ]
        };
        let coop = JobScheduler::with_workers(2).run(&mk()).unwrap();
        for quantum in [1u64, 4, 100] {
            let preempted = JobScheduler::with_workers(2)
                .preempt_quantum(quantum)
                .run(&mk())
                .unwrap();
            for (a, b) in coop.iter().zip(&preempted) {
                assert_eq!(a.output.gbest_fit, b.output.gbest_fit, "q={quantum} {}", a.name);
                assert_eq!(a.output.gbest_pos, b.output.gbest_pos, "q={quantum} {}", a.name);
                assert_eq!(a.output.history, b.output.history, "q={quantum} {}", a.name);
                assert_eq!(a.steps, b.steps, "q={quantum} {}", a.name);
            }
        }
    }

    #[test]
    fn session_round_cap_suspends_then_resume_completes_identically() {
        let mk = || {
            vec![
                spec("s1", EngineKind::Queue, 64, 20, 1),
                spec("s2", EngineKind::Queue, 64, 20, 2),
            ]
        };
        let reference = JobScheduler::with_workers(2).run(&mk()).unwrap();
        let scheduler = JobScheduler::with_workers(2);
        let specs = mk();
        let snap = match scheduler.run_session(&specs, None, Some(5), |_| {}).unwrap() {
            BatchRun::Suspended(snap) => snap,
            BatchRun::Complete(_) => panic!("40 job-steps cannot fit in 5 rounds"),
        };
        assert_eq!(snap.len(), 2);
        assert!(snap.iter().all(|j| j.stop.is_none()));
        let resumed = match scheduler.run_session(&specs, Some(&snap), None, |_| {}).unwrap() {
            BatchRun::Complete(outcomes) => outcomes,
            BatchRun::Suspended(_) => panic!("uncapped resume must complete"),
        };
        for (a, b) in reference.iter().zip(&resumed) {
            assert_eq!(a.output.gbest_fit, b.output.gbest_fit, "{}", a.name);
            assert_eq!(a.output.history, b.output.history, "{}", a.name);
            assert_eq!(a.steps, b.steps, "{}", a.name);
            assert_eq!(a.stop, b.stop, "{}", a.name);
        }
    }

    #[test]
    fn session_resume_rejects_mismatched_snapshots() {
        let specs = vec![spec("x", EngineKind::Queue, 32, 6, 1)];
        let scheduler = JobScheduler::with_workers(1);
        let snap = match scheduler.run_session(&specs, None, Some(1), |_| {}).unwrap() {
            BatchRun::Suspended(snap) => snap,
            BatchRun::Complete(_) => panic!("must suspend"),
        };
        // Length mismatch.
        let err = scheduler
            .run_session(&specs, Some(&[]), None, |_| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("0 jobs"), "{err}");
        // Name mismatch.
        let renamed = vec![spec("y", EngineKind::Queue, 32, 6, 1)];
        let err = scheduler
            .run_session(&renamed, Some(&snap), None, |_| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("\"x\""), "{err}");
        // Engine-kind mismatch.
        let rekind = vec![spec("x", EngineKind::Reduction, 32, 6, 1)];
        let err = scheduler
            .run_session(&rekind, Some(&snap), None, |_| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("queue"), "{err}");
        // Fitness mismatch: the swarm state is meaningless under another
        // function — must be a loud error, not a silently-wrong resume.
        let mut refit = spec("x", EngineKind::Queue, 32, 6, 1);
        refit.fitness = Arc::new(crate::fitness::Sphere);
        let err = scheduler
            .run_session(&[refit], Some(&snap), None, |_| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("cubic") && err.contains("sphere"), "{err}");
    }

    #[test]
    fn stop_reason_codes_roundtrip() {
        for reason in [
            StopReason::Exhausted,
            StopReason::TargetReached,
            StopReason::MaxIter,
            StopReason::Stalled,
        ] {
            assert_eq!(StopReason::from_code(reason.code()).unwrap(), reason);
        }
        assert!(StopReason::from_code(9).is_err());
    }

    #[test]
    fn xla_kinds_are_rejected() {
        let scheduler = JobScheduler::with_workers(1);
        let mut s = spec("x", EngineKind::Queue, 8, 2, 1);
        s.engine = EngineKind::XlaSync;
        let err = scheduler.run(&[s]).unwrap_err().to_string();
        assert!(err.contains("not schedulable"), "{err}");
    }

    #[test]
    fn empty_spec_list_is_fine() {
        let scheduler = JobScheduler::with_workers(1);
        assert!(scheduler.run(&[]).unwrap().is_empty());
    }
}
