//! Multi-job scheduler — many concurrent PSO jobs multiplexed over one
//! shared [`GridPool`].
//!
//! The step-wise engine core ([`crate::engine::Run`]) makes a run a
//! resumable object: all buffers live in the `Run`, a `step()` advances
//! one iteration, and nothing about the trajectory depends on *when* the
//! step executes. [`JobScheduler`] exploits exactly that: it prepares one
//! `Run` per [`JobSpec`], then interleaves single steps over the shared
//! worker pool under a [`SchedPolicy`] until every job hits a
//! [`TerminationCriteria`] bound or exhausts its iteration budget.
//!
//! **Determinism.** Because a `Run` owns its whole mutable state and pool
//! launches are serialized, a job's trajectory is bit-identical whether it
//! runs alone or interleaved with any number of other jobs — for the
//! bit-exact engines (CPU, Reduction, Loop-Unrolling, Queue). Queue-Lock
//! and Async-Persistent carry their documented intra-run races, but those
//! races are confined to the job's own `Run`: neighbours still cannot
//! perturb each other. `rust/tests/scheduler_determinism.rs` enforces the
//! bit-exact half.
//!
//! This is the ROADMAP's "many concurrent optimization jobs" seam: PSO-PS
//! (arXiv:2009.03816) treats PSO as a long-lived service, and
//! time-critical deployments (arXiv:1401.0546) need early termination and
//! bounded per-step latency — both fall out of step-wise runs plus this
//! scheduler.

use crate::config::{EngineKind, JobConfig};
use crate::engine::{self, ParallelSettings, Run};
use crate::exec::GridPool;
use crate::fitness::{by_name, Fitness, Objective};
use crate::pso::{PsoParams, RunOutput};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// When to stop a job before its `params.max_iter` budget.
///
/// All bounds are optional and combined with OR: the first one hit wins.
/// The run's own iteration budget always applies on top.
#[derive(Debug, Clone, Default)]
pub struct TerminationCriteria {
    /// Hard cap on scheduler steps (iterations) for this job.
    pub max_iter: Option<u64>,
    /// Stop once the global best is at least this good (`>=` under
    /// Maximize, `<=` under Minimize).
    pub target_fit: Option<f64>,
    /// Stop after this many consecutive steps without a global-best
    /// improvement.
    pub stall_window: Option<u64>,
}

impl TerminationCriteria {
    /// No early termination: run to the iteration budget.
    pub fn none() -> Self {
        Self::default()
    }

    /// Builder: cap scheduler steps.
    pub fn with_max_iter(mut self, steps: u64) -> Self {
        self.max_iter = Some(steps);
        self
    }

    /// Builder: stop at a target fitness.
    pub fn with_target_fit(mut self, fit: f64) -> Self {
        self.target_fit = Some(fit);
        self
    }

    /// Builder: stop after a stall.
    pub fn with_stall_window(mut self, steps: u64) -> Self {
        self.stall_window = Some(steps);
        self
    }

    /// Evaluate the criteria after a step. `steps` counts executed steps,
    /// `stalled` counts consecutive non-improving steps, `gbest` is the
    /// job's current best under `objective`.
    pub fn check(
        &self,
        objective: Objective,
        gbest: f64,
        steps: u64,
        stalled: u64,
    ) -> Option<StopReason> {
        if let Some(target) = self.target_fit {
            // Reached when the target is not strictly better than gbest.
            if !objective.better(target, gbest) {
                return Some(StopReason::TargetReached);
            }
        }
        if let Some(cap) = self.max_iter {
            if steps >= cap {
                return Some(StopReason::MaxIter);
            }
        }
        if let Some(window) = self.stall_window {
            if stalled >= window {
                return Some(StopReason::Stalled);
            }
        }
        None
    }
}

/// Why a job stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// The run's own `params.max_iter` budget is spent.
    Exhausted,
    /// [`TerminationCriteria::target_fit`] reached.
    TargetReached,
    /// [`TerminationCriteria::max_iter`] cap hit.
    MaxIter,
    /// [`TerminationCriteria::stall_window`] consecutive stale steps.
    Stalled,
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            StopReason::Exhausted => "exhausted",
            StopReason::TargetReached => "target-reached",
            StopReason::MaxIter => "max-iter",
            StopReason::Stalled => "stalled",
        };
        f.write_str(s)
    }
}

/// One tenant job: engine kind, workload, seed, and stop bounds.
pub struct JobSpec {
    /// Display name (batch-config section name).
    pub name: String,
    /// Plane-A engine kind driving this job.
    pub engine: EngineKind,
    /// The workload.
    pub params: PsoParams,
    /// Fitness function (shared, engines borrow it per step).
    pub fitness: Arc<dyn Fitness + Send>,
    /// Optimization sense.
    pub objective: Objective,
    /// Master seed.
    pub seed: u64,
    /// Early-termination bounds.
    pub termination: TerminationCriteria,
    /// Step budget this job would like to finish within — consumed by
    /// [`SchedPolicy::EarliestDeadlineFirst`]; ignored by round-robin.
    pub deadline: Option<u64>,
}

impl JobSpec {
    /// A job with default objective/termination (run to budget).
    pub fn new(
        name: &str,
        engine: EngineKind,
        params: PsoParams,
        fitness: Arc<dyn Fitness + Send>,
        objective: Objective,
        seed: u64,
    ) -> Self {
        Self {
            name: name.to_string(),
            engine,
            params,
            fitness,
            objective,
            seed,
            termination: TerminationCriteria::none(),
            deadline: None,
        }
    }

    /// Build a spec from a batch-config job entry.
    pub fn from_config(cfg: &JobConfig) -> Result<Self> {
        let fitness = by_name(&cfg.fitness)
            .with_context(|| format!("job {}: unknown fitness {}", cfg.name, cfg.fitness))?;
        if !cfg.engine.is_plane_a() {
            bail!(
                "job {}: engine {} is not schedulable (Plane-A only)",
                cfg.name,
                cfg.engine
            );
        }
        let objective = cfg.objective.unwrap_or(fitness.default_objective());
        let params =
            PsoParams::for_fitness(fitness.as_ref(), cfg.particles, cfg.dim, cfg.iters, 0.5);
        Ok(Self {
            name: cfg.name.clone(),
            engine: cfg.engine,
            params,
            fitness: Arc::from(fitness),
            objective,
            seed: cfg.seed,
            termination: TerminationCriteria {
                max_iter: cfg.max_steps,
                target_fit: cfg.target_fitness,
                stall_window: cfg.stall_window,
            },
            deadline: cfg.deadline,
        })
    }
}

/// Which live job gets the next step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Cycle through live jobs, one step each — fair progress, bounded
    /// per-job latency between steps.
    #[default]
    RoundRobin,
    /// Greedy EDF: always step the live job with the smallest remaining
    /// deadline slack (`deadline - steps_done`; jobs without a deadline
    /// rank last). Ties break on job index, so scheduling is fully
    /// deterministic.
    EarliestDeadlineFirst,
}

impl SchedPolicy {
    /// Parse CLI/config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "roundrobin" | "rr" => Some(Self::RoundRobin),
            "edf" | "deadline" | "earliestdeadlinefirst" => Some(Self::EarliestDeadlineFirst),
            _ => None,
        }
    }
}

impl std::fmt::Display for SchedPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedPolicy::RoundRobin => f.write_str("round-robin"),
            SchedPolicy::EarliestDeadlineFirst => f.write_str("edf"),
        }
    }
}

/// Telemetry for one scheduler step of one job.
#[derive(Debug, Clone)]
pub struct JobReport<'a> {
    /// Index of the job in the spec slice.
    pub job: usize,
    /// Job name.
    pub name: &'a str,
    /// Steps (iterations) the job has executed, this one included.
    pub iter: u64,
    /// The job's global-best fitness after the step.
    pub gbest_fit: f64,
    /// Whether the step improved the job's global best.
    pub improved: bool,
    /// Set on the job's final step.
    pub finished: Option<StopReason>,
}

/// Final result of one scheduled job.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// Job name.
    pub name: String,
    /// Engine kind that ran it.
    pub engine: EngineKind,
    /// Why it stopped.
    pub stop: StopReason,
    /// Steps (iterations) executed.
    pub steps: u64,
    /// The run's output — for the bit-exact engines, identical to the
    /// same job run solo.
    pub output: RunOutput,
}

/// Multiplexes N concurrent jobs over one shared [`GridPool`].
pub struct JobScheduler {
    settings: ParallelSettings,
    policy: SchedPolicy,
}

struct LiveJob<'a> {
    run: Box<dyn Run + 'a>,
    steps: u64,
    stalled: u64,
    stop: Option<StopReason>,
    deadline: Option<u64>,
}

impl JobScheduler {
    /// Scheduler over the given pool/geometry (round-robin by default).
    pub fn new(settings: ParallelSettings) -> Self {
        Self {
            settings,
            policy: SchedPolicy::RoundRobin,
        }
    }

    /// Scheduler on a fresh pool with `workers` threads (0 = all cores).
    pub fn with_workers(workers: usize) -> Self {
        Self::new(ParallelSettings::with_workers(workers))
    }

    /// Override the stepping policy.
    pub fn policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// The shared pool jobs are multiplexed over.
    pub fn pool(&self) -> &Arc<GridPool> {
        &self.settings.pool
    }

    /// Run all jobs to termination, discarding telemetry.
    pub fn run(&self, specs: &[JobSpec]) -> Result<Vec<JobOutcome>> {
        self.run_with(specs, |_| {})
    }

    /// Run all jobs to termination, streaming a [`JobReport`] per step.
    ///
    /// Outcomes are returned in spec order regardless of completion order.
    pub fn run_with<F: FnMut(&JobReport<'_>)>(
        &self,
        specs: &[JobSpec],
        mut telemetry: F,
    ) -> Result<Vec<JobOutcome>> {
        // Prepare every run up front: all allocation happens here, steps
        // stay allocation-free on the hot path.
        let mut engines = Vec::with_capacity(specs.len());
        for spec in specs {
            let engine = engine::build_with(spec.engine, self.settings.clone())
                .with_context(|| {
                    format!("job {}: engine {} is not schedulable", spec.name, spec.engine)
                })?;
            engines.push(engine);
        }
        let mut live: Vec<LiveJob<'_>> = Vec::with_capacity(specs.len());
        for (engine, spec) in engines.iter_mut().zip(specs) {
            let fitness: &dyn Fitness = &*spec.fitness;
            live.push(LiveJob {
                run: engine.prepare(&spec.params, fitness, spec.objective, spec.seed),
                steps: 0,
                stalled: 0,
                stop: None,
                deadline: spec.deadline,
            });
        }

        let mut finished = 0usize;
        let mut cursor = 0usize;
        while finished < live.len() {
            let idx = match self.policy {
                SchedPolicy::RoundRobin => {
                    let idx = next_live(&live, cursor).expect("unfinished job exists");
                    cursor = (idx + 1) % live.len();
                    idx
                }
                SchedPolicy::EarliestDeadlineFirst => {
                    earliest_deadline(&live).expect("unfinished job exists")
                }
            };
            let job = &mut live[idx];
            let spec = &specs[idx];
            let report = job.run.step();
            job.steps += 1;
            if report.improved {
                job.stalled = 0;
            } else {
                job.stalled += 1;
            }
            // Criteria outrank budget exhaustion so a target hit on the
            // final iteration still reports TargetReached (matching the
            // precedence TerminationCriteria::check documents).
            let stop = spec
                .termination
                .check(spec.objective, report.gbest_fit, job.steps, job.stalled)
                .or(report.done.then_some(StopReason::Exhausted));
            telemetry(&JobReport {
                job: idx,
                name: &spec.name,
                iter: job.steps,
                gbest_fit: report.gbest_fit,
                improved: report.improved,
                finished: stop,
            });
            if stop.is_some() {
                job.stop = stop;
                finished += 1;
            }
        }

        Ok(live
            .into_iter()
            .zip(specs)
            .map(|(job, spec)| JobOutcome {
                name: spec.name.clone(),
                engine: spec.engine,
                stop: job.stop.expect("every job terminated"),
                steps: job.steps,
                output: job.run.finish(),
            })
            .collect())
    }
}

/// Next unfinished job at or after `cursor` (cyclic scan).
fn next_live(live: &[LiveJob<'_>], cursor: usize) -> Option<usize> {
    let n = live.len();
    (0..n)
        .map(|k| (cursor + k) % n)
        .find(|&i| live[i].stop.is_none())
}

/// Unfinished job with the least deadline slack (ties → lowest index).
fn earliest_deadline(live: &[LiveJob<'_>]) -> Option<usize> {
    live.iter()
        .enumerate()
        .filter(|(_, j)| j.stop.is_none())
        .min_by_key(|(i, j)| {
            let slack = j
                .deadline
                .map(|d| d.saturating_sub(j.steps))
                .unwrap_or(u64::MAX);
            (slack, *i)
        })
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fitness::Cubic;

    fn spec(name: &str, engine: EngineKind, n: usize, iters: u64, seed: u64) -> JobSpec {
        JobSpec::new(
            name,
            engine,
            PsoParams::paper_1d(n, iters),
            Arc::new(Cubic),
            Objective::Maximize,
            seed,
        )
    }

    #[test]
    fn criteria_target_fit_respects_objective() {
        let c = TerminationCriteria::none().with_target_fit(10.0);
        let max = Objective::Maximize;
        let min = Objective::Minimize;
        assert_eq!(c.check(max, 9.0, 1, 0), None);
        assert_eq!(c.check(max, 10.0, 1, 0), Some(StopReason::TargetReached));
        assert_eq!(c.check(max, 11.0, 1, 0), Some(StopReason::TargetReached));
        assert_eq!(c.check(min, 11.0, 1, 0), None);
        assert_eq!(c.check(min, 9.0, 1, 0), Some(StopReason::TargetReached));
    }

    #[test]
    fn criteria_max_iter_and_stall() {
        let c = TerminationCriteria::none()
            .with_max_iter(5)
            .with_stall_window(3);
        let max = Objective::Maximize;
        assert_eq!(c.check(max, 0.0, 4, 0), None);
        assert_eq!(c.check(max, 0.0, 5, 0), Some(StopReason::MaxIter));
        assert_eq!(c.check(max, 0.0, 2, 3), Some(StopReason::Stalled));
        // Target outranks the caps when several bounds trip at once.
        let c = c.with_target_fit(f64::NEG_INFINITY);
        assert_eq!(c.check(max, 0.0, 5, 3), Some(StopReason::TargetReached));
    }

    #[test]
    fn policies_parse_and_display() {
        assert_eq!(SchedPolicy::parse("round-robin"), Some(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::parse("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(
            SchedPolicy::parse("EDF"),
            Some(SchedPolicy::EarliestDeadlineFirst)
        );
        assert_eq!(SchedPolicy::parse("fifo"), None);
        assert_eq!(SchedPolicy::RoundRobin.to_string(), "round-robin");
    }

    #[test]
    fn round_robin_interleaves_fairly() {
        let scheduler = JobScheduler::with_workers(2);
        let specs = vec![
            spec("a", EngineKind::Queue, 64, 10, 1),
            spec("b", EngineKind::Queue, 64, 10, 2),
        ];
        let mut order = Vec::new();
        let outcomes = scheduler
            .run_with(&specs, |r| order.push(r.job))
            .unwrap();
        // Strict alternation: a b a b …
        for (k, &j) in order.iter().enumerate() {
            assert_eq!(j, k % 2, "step {k} went to job {j}");
        }
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert_eq!(o.steps, 10);
            assert_eq!(o.stop, StopReason::Exhausted);
            assert_eq!(o.output.iters, 10);
        }
    }

    #[test]
    fn edf_runs_tight_deadlines_first() {
        let scheduler = JobScheduler::with_workers(2).policy(SchedPolicy::EarliestDeadlineFirst);
        let mut a = spec("loose", EngineKind::Queue, 64, 8, 1);
        a.deadline = Some(100);
        let mut b = spec("tight", EngineKind::Queue, 64, 8, 2);
        b.deadline = Some(8);
        let specs = vec![a, b];
        let mut finish_order = Vec::new();
        scheduler
            .run_with(&specs, |r| {
                if r.finished.is_some() {
                    finish_order.push(r.job);
                }
            })
            .unwrap();
        assert_eq!(finish_order, vec![1, 0], "tight deadline must finish first");
    }

    #[test]
    fn xla_kinds_are_rejected() {
        let scheduler = JobScheduler::with_workers(1);
        let mut s = spec("x", EngineKind::Queue, 8, 2, 1);
        s.engine = EngineKind::XlaSync;
        let err = scheduler.run(&[s]).unwrap_err().to_string();
        assert!(err.contains("not schedulable"), "{err}");
    }

    #[test]
    fn empty_spec_list_is_fine() {
        let scheduler = JobScheduler::with_workers(1);
        assert!(scheduler.run(&[]).unwrap().is_empty());
    }
}
