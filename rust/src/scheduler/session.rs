//! The stateful scheduling session: job slots, round stepping, and the
//! dynamic admission/cancellation surface the service layer builds on.
//!
//! [`super::JobScheduler::run_session_with`] used to own this logic as
//! one monolithic loop over a fixed spec slice. A live service cannot
//! work that way — tenants submit and cancel jobs while the scheduler is
//! running — so the loop's state is now a first-class [`Session`]:
//!
//! * **Job slots.** Jobs live in a slot table (`Vec<Option<SlotJob>>`).
//!   [`Session::admit`] fills the lowest free slot (recycling the slots
//!   of reaped/cancelled jobs) and pins the job to pool stream
//!   `slot % S` — exactly the pinning rule the fixed-batch path always
//!   used, so a batch admitted up front is indistinguishable from the
//!   old code path.
//! * **Round-boundary mutation.** [`Session::round`] steps one
//!   scheduling round; admission ([`Session::admit`]), cancellation
//!   ([`Session::cancel`]) and reaping ([`Session::reap`]) only ever
//!   happen *between* rounds, when every grid is quiescent and every
//!   `Run` sits at a step boundary. That keeps the determinism proof
//!   intact: a `Run` owns all of its mutable state, a launch never spans
//!   runs, and now additionally no job is ever created or destroyed
//!   while a round is in flight — so a job's trajectory is bit-identical
//!   regardless of *when* its neighbours were admitted or cancelled
//!   (`rust/tests/scheduler_determinism.rs` § late admission).
//! * **Unique names.** Job names are `Arc<str>` identity keys (the
//!   service addresses jobs by name), so admission rejects duplicates
//!   loudly instead of letting a second `"alpha"` shadow the first.
//! * **Zero-allocation steady state.** All round bookkeeping lives in
//!   [`RoundState`] buffers grown only at admission time, and the
//!   executors are (re)created only when the occupied-slot count grows —
//!   a warmed-up round still performs zero heap allocations for the
//!   bit-exact engines (`rust/tests/zero_alloc.rs`), including the
//!   service loop's empty-control-queue rounds.
//! * **Swarm packing.** With [`super::JobScheduler::pack`] enabled, the
//!   session groups compatible live Queue jobs into
//!   [`PackedRun`] packs at round boundaries and steps every pack member
//!   with one launch pair per round instead of one dispatch per job —
//!   the fleet-level megabatch (`DESIGN.md` § Pack execution). Packing
//!   is invisible to everything else: members keep their slot, name,
//!   stream record, per-round report and checkpoint semantics, and a
//!   member leaving the pack (cancel, termination, preemption,
//!   dissolution, drain) extracts its slice as an ordinary parked
//!   checkpoint. Pack membership changes only at round boundaries, via
//!   [`Session::reconcile_packs`], so the determinism story above is
//!   unchanged — packed and standalone trajectories are bit-identical.
//!
//! ## Lifetime erasure
//!
//! A [`Run`] borrows its fitness (`Engine::prepare<'a>`), which made the
//! old `LiveJob<'a>` borrow the caller's spec slice. A dynamic session
//! *owns* its specs, so a slot stores the run with an **erased**
//! lifetime next to the `JobSpec` whose `Arc<dyn Fitness>` it borrows —
//! the same discipline as the executor module's lifetime-erased command
//! pointers. Soundness rests on three invariants, all local to this
//! module: the `Arc` pointee is heap-allocated and never moves; a slot
//! never replaces `spec.fitness` while `run` is `Some`; and `SlotJob`
//! declares `run` before `spec`, so the run (and with it the erased
//! borrow) always drops first.

use super::executor::{spin_budget, StreamExecutors};
use super::{
    effective_batch, JobOutcome, JobReport, JobScheduler, JobSpec, SchedPolicy, StopReason,
};
use crate::checkpoint::{JobCheckpoint, RunCheckpoint, RunKind};
use crate::config::EngineKind;
use crate::engine::{self, PackedRun, ParallelSettings, Run, StepReport};
use crate::fitness::{Fitness, Objective};
use crate::telemetry::{bump, trace, Counter, PhaseClock, Series, TraceKind};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// One occupied job slot.
struct SlotJob {
    /// The live run — `None` while the job is suspended to `parked`.
    /// Declared FIRST: its erased borrow of `spec.fitness` must end
    /// before `spec` (and the `Arc` it holds) drops.
    run: Option<Box<dyn Run + 'static>>,
    /// The suspension checkpoint of an inactive job (shared, so snapshot
    /// persistence never deep-copies a parked swarm).
    parked: Option<Arc<RunCheckpoint>>,
    /// The job's spec — owns the `Arc<dyn Fitness>` the run borrows.
    spec: JobSpec,
    steps: u64,
    stalled: u64,
    stop: Option<StopReason>,
    /// Pool stream the job's launches are currently pinned to. A
    /// suspended job loses its pinning and may be restored onto any free
    /// stream (migration).
    stream: usize,
    /// Steps executed since the last (re)activation — the preemption
    /// quantum counts against this, not lifetime steps.
    active_steps: u64,
    /// Pack membership: `(pack slot, member index)` while the job's
    /// state lives inside a [`PackedRun`] slab (`run` and `parked` are
    /// both `None` then).
    pack: Option<(usize, usize)>,
    /// Sticky opt-out: set when preemption extracts the job from a pack,
    /// so it rejoins the standalone preemptive pool instead of being
    /// re-packed next reconcile.
    no_pack: bool,
}

/// Extend a fitness borrow to `'static` so the run can live in the same
/// slot as the spec that owns it.
///
/// # Safety
/// The caller must guarantee the `Arc<dyn Fitness>` inside `spec` stays
/// alive (and is never replaced) for as long as anything produced from
/// the returned reference lives. [`SlotJob`]'s field order and the
/// module's no-reassignment invariant uphold this for every use here.
unsafe fn erased_fitness(spec: &JobSpec) -> &'static dyn Fitness {
    let fitness: &dyn Fitness = &*spec.fitness;
    // SAFETY: lifetime extension per this function's contract (the Arc
    // outlives every artifact of the returned reference).
    unsafe { std::mem::transmute::<&dyn Fitness, &'static dyn Fitness>(fitness) }
}

/// Read-only view of one occupied slot (the service's `status` rows).
#[derive(Debug, Clone, Copy)]
pub struct JobView<'a> {
    /// Slot index (stable for the job's lifetime, recycled afterwards).
    pub slot: usize,
    /// Job name.
    pub name: &'a str,
    /// Engine kind.
    pub engine: crate::config::EngineKind,
    /// Steps executed so far.
    pub steps: u64,
    /// The run's iteration budget.
    pub max_iter: u64,
    /// Current global-best fitness.
    pub gbest_fit: f64,
    /// Pool stream the job is pinned to.
    pub stream: usize,
    /// Set once the job terminated (awaiting [`Session::reap`]).
    pub stop: Option<StopReason>,
    /// Owning tenant (`None` = the anonymous tenant) — the service's
    /// quota accounting reads per-tenant usage straight off this view.
    pub tenant: Option<&'a str>,
}

/// Reusable per-session scheduling buffers, grown only at admission time
/// so the steady-state loop performs zero heap allocations per round.
struct RoundState {
    /// Policy-ordering scratch (live slot indices).
    order: Vec<usize>,
    /// Streams taken this round.
    used: Vec<bool>,
    /// The round's picks: `(slot index, stream)`.
    picked: Vec<(usize, usize)>,
    /// Slot index per submitted executor slot, in submission order.
    inflight: Vec<usize>,
    /// The round's step reports, sorted by slot index before delivery.
    reports: Vec<(usize, StepReport)>,
    /// Per-slot tenant step totals (weighted-fair policy scratch; indexed
    /// by slot, refreshed each round from capacity reserved at admission).
    keys: Vec<u64>,
}

impl RoundState {
    fn new(streams: usize) -> Self {
        Self {
            order: Vec::new(),
            used: vec![false; streams],
            picked: Vec::new(),
            inflight: Vec::new(),
            reports: Vec::new(),
            keys: Vec::new(),
        }
    }

    /// Pre-size every buffer for `slots` job slots on `streams` streams
    /// (called at admission, never inside a round). The report buffer is
    /// sized for *every* slot: a round can report all packed members on
    /// top of the standalone picks.
    fn ensure(&mut self, streams: usize, slots: usize) {
        let width = streams.min(slots.max(1));
        reserve_to(&mut self.order, slots);
        reserve_to(&mut self.picked, width);
        reserve_to(&mut self.inflight, width);
        reserve_to(&mut self.reports, slots.max(1));
        reserve_to(&mut self.keys, slots);
    }
}

fn reserve_to<T>(v: &mut Vec<T>, cap: usize) {
    if v.capacity() < cap {
        v.reserve(cap - v.len());
    }
}

/// The session's packing knobs (`None` on the pack field = packing off).
#[derive(Clone, Copy)]
struct PackPolicy {
    /// Smallest compatible group worth packing (≥ 2).
    min: usize,
    /// Largest pack formed (0 = unbounded).
    max: usize,
}

/// One live pack: the fused run plus the slot index of every member
/// (`usize::MAX` = tombstone, the member was extracted). The pack's
/// launches go to the stream its `PackedRun` settings were pinned to at
/// formation time.
struct PackSlot {
    run: PackedRun,
    members: Vec<usize>,
}

/// A live scheduling session over one shared pool: jobs can be admitted,
/// stepped round by round, cancelled, reaped and snapshotted — see the
/// module docs. [`JobScheduler::run_session_with`] drives one of these
/// for the fixed-batch path; the service layer drives one for live
/// traffic.
pub struct Session {
    settings: ParallelSettings,
    policy: SchedPolicy,
    batch_steps: u64,
    preempt_quantum: Option<u64>,
    spawn_per_round: bool,
    streams: usize,
    /// Declared BEFORE `slots`: fields drop in declaration order, and a
    /// panic unwinding mid-round (e.g. a fitness function panicking on
    /// the scheduling thread while executors still step their submitted
    /// runs) must join the executor threads *before* the runs they hold
    /// raw pointers into are freed. The pre-refactor code got this from
    /// local-variable drop order; the struct must encode it explicitly.
    executors: Option<StreamExecutors>,
    slots: Vec<Option<SlotJob>>,
    /// Pack slots (swarm-packing mode; always empty otherwise). Declared
    /// after `slots` only for tidiness — a `PackedRun` owns its member
    /// fitness `Arc`s outright, so pack drop order is unconstrained.
    packs: Vec<Option<PackSlot>>,
    /// Packing knobs (`None` = packing disabled).
    pack_policy: Option<PackPolicy>,
    /// Membership may be stale: re-run `reconcile_packs` at the next
    /// round boundary. Set by admission, extraction and termination;
    /// false in the steady state so reconciliation is a single branch.
    pack_dirty: bool,
    /// Occupied slots (live + terminated-but-unreaped).
    occupied: usize,
    /// Occupied slots that have not terminated yet.
    live: usize,
    rounds: u64,
    rs: RoundState,
}

impl Session {
    pub(super) fn new(sched: &JobScheduler) -> Self {
        let streams = sched.settings.pool.streams();
        Self {
            settings: sched.settings.clone(),
            policy: sched.policy,
            batch_steps: sched.batch_steps,
            preempt_quantum: sched.preempt_quantum,
            spawn_per_round: sched.spawn_per_round,
            streams,
            executors: None,
            slots: Vec::new(),
            packs: Vec::new(),
            pack_policy: sched.pack.then(|| PackPolicy {
                min: sched.pack_min.max(2),
                max: sched.pack_max,
            }),
            pack_dirty: false,
            occupied: 0,
            live: 0,
            rounds: 0,
            rs: RoundState::new(streams),
        }
    }

    /// Occupied slots that have not terminated yet.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Occupied slots (live + terminated-but-unreaped).
    pub fn occupied(&self) -> usize {
        self.occupied
    }

    /// Scheduling rounds executed so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// Concurrent streams of the underlying pool.
    pub fn streams(&self) -> usize {
        self.streams
    }

    /// The pool stream the job in `slot` is currently pinned to
    /// (`None` for a free slot). This is the session's own record —
    /// callers reporting a job's placement must read it here rather
    /// than re-deriving the pinning rule, which migration can overrule.
    pub fn stream_of(&self, slot: usize) -> Option<usize> {
        self.slots.get(slot)?.as_ref().map(|job| job.stream)
    }

    /// Reject a name that is already an occupied slot's identity key.
    fn check_unique(&self, name: &str) -> Result<()> {
        if self.slots.iter().flatten().any(|j| &*j.spec.name == name) {
            bail!("duplicate job name {name:?}: job names are unique identity keys");
        }
        Ok(())
    }

    /// The lowest free slot, or a fresh one at the end of the table.
    fn free_slot(&self) -> usize {
        self.slots
            .iter()
            .position(Option::is_none)
            .unwrap_or(self.slots.len())
    }

    fn insert(&mut self, idx: usize, job: SlotJob) {
        if idx == self.slots.len() {
            self.slots.push(Some(job));
        } else {
            debug_assert!(self.slots[idx].is_none(), "insert into an occupied slot");
            self.slots[idx] = Some(job);
        }
        self.occupied += 1;
        self.rs.ensure(self.streams, self.slots.len());
    }

    /// Admit a new job: prepare its run (all buffers allocated here, the
    /// hot path stays allocation-free), pin it to stream `slot % S`, and
    /// return its slot index. Rejects non-schedulable engines and
    /// duplicate names.
    pub fn admit(&mut self, spec: JobSpec) -> Result<usize> {
        self.check_unique(&spec.name)?;
        let idx = self.free_slot();
        let stream = idx % self.streams;
        let mut engine = engine::build_with(spec.engine, self.settings.clone().on_stream(idx))
            .with_context(|| {
                format!("job {}: engine {} is not schedulable", spec.name, spec.engine)
            })?;
        // SAFETY: the run lands in the same slot as `spec`; the slot
        // drops it first and never swaps `spec.fitness` (module docs).
        let fitness = unsafe { erased_fitness(&spec) };
        let run = engine.prepare(&spec.params, fitness, spec.objective, spec.seed);
        let job = SlotJob {
            run: Some(run),
            parked: None,
            spec,
            steps: 0,
            stalled: 0,
            stop: None,
            stream,
            active_steps: 0,
            pack: None,
            no_pack: false,
        };
        self.insert(idx, job);
        self.live += 1;
        self.pack_dirty = true;
        bump(Counter::JobsAdmitted);
        trace(TraceKind::Admit, idx as u64, 0);
        Ok(idx)
    }

    /// Admit a job suspended in an earlier session: validate the
    /// checkpoint against the spec and park it — the run is restored
    /// lazily when the policy first picks it, onto whichever stream is
    /// free that round (migration).
    pub fn admit_resumed(&mut self, spec: JobSpec, ckpt: &JobCheckpoint) -> Result<usize> {
        self.check_unique(&spec.name)?;
        let idx = self.free_slot();
        if ckpt.name != spec.name {
            bail!(
                "resume snapshot job {idx} is {:?}, spec says {:?}",
                ckpt.name,
                spec.name
            );
        }
        ckpt.run
            .validate()
            .with_context(|| format!("resuming job {}", spec.name))?;
        if RunKind::from_engine(spec.engine) != Some(ckpt.run.kind) {
            bail!(
                "resuming job {}: checkpoint is a {} run, spec wants engine {}",
                spec.name,
                ckpt.run.kind,
                spec.engine
            );
        }
        // The swarm's fit/pbest arrays were computed under the recorded
        // fitness — continuing under a different one would be silently
        // wrong, never do it.
        if ckpt.fitness != spec.fitness.name() {
            bail!(
                "resuming job {}: checkpoint was taken under fitness {:?}, spec uses {:?}",
                spec.name,
                ckpt.fitness,
                spec.fitness.name()
            );
        }
        let stop = ckpt.stop.map(StopReason::from_code).transpose()?;
        let job = SlotJob {
            run: None,
            // Arc clone: resuming shares the caller's checkpoint instead
            // of deep-copying the swarm arrays.
            parked: Some(Arc::clone(&ckpt.run)),
            steps: ckpt.run.iter,
            stalled: ckpt.stalled,
            stop,
            stream: idx % self.streams,
            active_steps: 0,
            pack: None,
            no_pack: false,
            spec,
        };
        self.insert(idx, job);
        if stop.is_none() {
            self.live += 1;
            self.pack_dirty = true;
        }
        bump(Counter::JobsAdmitted);
        trace(TraceKind::Admit, idx as u64, 1);
        Ok(idx)
    }

    /// Cancel a live job by name at this round boundary: the slot is
    /// freed immediately (recyclable by the next admission) and the
    /// outcome — stop reason [`StopReason::Cancelled`], output as of the
    /// executed steps — is returned. Cancelling an unknown or
    /// already-terminated job is a loud error.
    pub fn cancel(&mut self, name: &str) -> Result<JobOutcome> {
        let idx = self
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|j| &*j.spec.name == name))
            .with_context(|| format!("no scheduled job named {name:?}"))?;
        {
            let job = self.slots[idx].as_ref().expect("position hit");
            if let Some(stop) = job.stop {
                bail!("job {name:?} already terminated ({stop})");
            }
        }
        let mut job = self.slots[idx].take().expect("position hit");
        self.occupied -= 1;
        self.live -= 1;
        job.stop = Some(StopReason::Cancelled);
        if let Some((p, m)) = job.pack.take() {
            // Cancelling a packed member extracts its slice out of the
            // slab — the outcome path below runs off the extracted
            // checkpoint, exactly like a suspended standalone job.
            let ps = self.packs[p].as_mut().expect("member's pack is occupied");
            job.parked = Some(Arc::new(ps.run.extract_member(m)));
            ps.members[m] = usize::MAX;
            if ps.run.live_members() == 0 {
                self.packs[p] = None;
                bump(Counter::PacksDissolved);
                trace(TraceKind::PackDissolve, p as u64, 0);
            }
            self.pack_dirty = true;
        }
        bump(Counter::JobsCancelled);
        trace(TraceKind::Cancel, idx as u64, 0);
        finish_slot(job, &self.settings, idx)
    }

    /// Free every terminated slot, handing its [`JobOutcome`] to `f` in
    /// slot order. The freed slots are recycled by later admissions.
    pub fn reap<F: FnMut(JobOutcome)>(&mut self, mut f: F) -> Result<()> {
        let mut clock = PhaseClock::start();
        for idx in 0..self.slots.len() {
            if self.slots[idx].as_ref().is_some_and(|j| j.stop.is_some()) {
                let job = self.slots[idx].take().expect("checked occupied");
                self.occupied -= 1;
                let outcome = finish_slot(job, &self.settings, idx)?;
                bump(Counter::JobsFinished);
                trace(TraceKind::Finish, idx as u64, outcome.stop.code() as u64);
                f(outcome);
            }
        }
        clock.lap(Series::RoundReapNs);
        Ok(())
    }

    /// Consume the session into outcomes for every occupied slot, in
    /// slot order. Every occupied job must have terminated.
    pub fn into_outcomes(mut self) -> Result<Vec<JobOutcome>> {
        self.unpack_all();
        let mut outcomes = Vec::with_capacity(self.occupied);
        for idx in 0..self.slots.len() {
            let Some(job) = self.slots[idx].take() else {
                continue;
            };
            outcomes.push(finish_slot(job, &self.settings, idx)?);
        }
        Ok(outcomes)
    }

    /// One [`JobCheckpoint`] per occupied slot, in slot order — active
    /// jobs checkpoint their live runs (a copy is unavoidable: the run
    /// keeps stepping), packed jobs slice their member state out of the
    /// pack slab (also a copy, and indistinguishable from a solo
    /// checkpoint at the same iteration — a snapshot taken off a packed
    /// session resumes bit-identically on a non-packed one), while
    /// suspended jobs share their parked checkpoint via `Arc` instead of
    /// deep-copying it.
    pub fn snapshot(&self) -> Vec<JobCheckpoint> {
        self.slots
            .iter()
            .flatten()
            .map(|job| JobCheckpoint {
                name: job.spec.name.clone(),
                fitness: job.spec.fitness.name().to_string(),
                stalled: job.stalled,
                stop: job.stop.map(StopReason::code),
                target_fit: job.spec.termination.target_fit,
                stall_window: job.spec.termination.stall_window,
                max_steps: job.spec.termination.max_iter,
                deadline: job.spec.deadline,
                run: match (&job.run, job.pack) {
                    (Some(run), _) => Arc::new(run.checkpoint()),
                    (None, Some((p, m))) => Arc::new(
                        self.packs[p]
                            .as_ref()
                            .expect("member's pack is occupied")
                            .run
                            .checkpoint_member(m),
                    ),
                    (None, None) => Arc::clone(
                        job.parked
                            .as_ref()
                            .expect("inactive job holds its checkpoint"),
                    ),
                },
            })
            .collect()
    }

    /// Visit every occupied slot's status row, in slot order.
    pub fn jobs<F: FnMut(JobView<'_>)>(&self, mut f: F) {
        for (i, slot) in self.slots.iter().enumerate() {
            let Some(job) = slot else { continue };
            f(JobView {
                slot: i,
                name: &job.spec.name,
                engine: job.spec.engine,
                steps: job.steps,
                max_iter: job.spec.params.max_iter,
                gbest_fit: match (&job.run, job.pack) {
                    (Some(run), _) => run.gbest_fit(),
                    (None, Some((p, m))) => self.packs[p]
                        .as_ref()
                        .expect("member's pack is occupied")
                        .run
                        .member_gbest_fit(m),
                    (None, None) => {
                        job.parked
                            .as_ref()
                            .expect("inactive job holds its checkpoint")
                            .gbest_fit
                    }
                },
                stream: job.stream,
                stop: job.stop,
                tenant: job.spec.tenant.as_deref(),
            });
        }
    }

    /// (Re)create the persistent executors when the occupied-slot count
    /// outgrew them. A pure comparison in the steady state — no
    /// allocation unless an admission actually raised the width.
    fn ensure_executors(&mut self) {
        if self.spawn_per_round || self.streams <= 1 || self.occupied <= 1 {
            return;
        }
        let needed = self.streams.min(self.occupied) - 1;
        let have = self.executors.as_ref().map_or(0, StreamExecutors::count);
        if needed > have {
            let total = self.settings.pool.workers() + self.streams + needed;
            self.executors = Some(StreamExecutors::new(needed, spin_budget(total)));
        }
    }

    /// Execute one scheduling round: pick up to `S` live jobs under the
    /// policy, step them (in parallel across streams), deliver their
    /// reports to `telemetry` in slot order, and apply termination and
    /// preemption. Calling with no live job is a loud error (a caller's
    /// drive loop must check [`live`](Self::live), and a misuse should
    /// surface as the `Result` this signature advertises, not a panic
    /// deep in the stepping machinery).
    pub fn round<F: FnMut(&JobReport<'_>)>(&mut self, telemetry: &mut F) -> Result<()> {
        if self.live == 0 {
            bail!("scheduling round requested with no live job");
        }
        // Phase clock: one Instant read per phase boundary, recorded into
        // the round-split histograms. Inert (no clock reads) when
        // telemetry is disabled, and never inside engine math — the
        // step phase is timed around `step_many`, not within it.
        let mut clock = PhaseClock::start();
        self.reconcile_packs()?;
        self.ensure_executors();
        self.rounds += 1;
        bump(Counter::Rounds);
        match self.policy {
            SchedPolicy::RoundRobin => pick_round_robin(&self.slots, self.streams, &mut self.rs),
            SchedPolicy::EarliestDeadlineFirst => pick_edf(&self.slots, self.streams, &mut self.rs),
            SchedPolicy::WeightedFair => pick_weighted_fair(&self.slots, self.streams, &mut self.rs),
        }
        clock.lap(Series::RoundPickNs);
        debug_assert!(
            !self.rs.picked.is_empty()
                || self.packs.iter().flatten().any(|p| p.run.live_members() > 0),
            "unfinished job exists"
        );
        self.rs.reports.clear();
        self.step_packs();
        self.step_round(&mut clock)?;
        self.rs.reports.sort_unstable_by_key(|&(i, _)| i);
        apply_reports(&mut self.slots, &self.rs, &mut self.live, telemetry);
        clock.lap(Series::RoundGbestNs);
        // Preemption: once a picked job has spent its quantum and the
        // live set still outnumbers the streams, suspend it — its
        // buffers are MOVED into a checkpoint (no deep copy) and its
        // stream frees up for a neighbour next round.
        let preempting = self.preempt_quantum.filter(|_| self.live > self.streams);
        if let Some(quantum) = preempting {
            for k in 0..self.rs.picked.len() {
                let (idx, _) = self.rs.picked[k];
                let job = self.slots[idx].as_mut().expect("picked job is occupied");
                if job.stop.is_none() && job.active_steps >= quantum {
                    if let Some(run) = job.run.take() {
                        job.parked = Some(Arc::new(run.into_checkpoint()));
                    }
                }
            }
        }
        // Pack maintenance: members that terminated this round leave the
        // pack (their outcome runs off the extracted checkpoint), and —
        // under preemption pressure — packed members over quantum are
        // handed to the standalone preemptive pool.
        self.sweep_packs(preempting);
        Ok(())
    }

    /// Step every live pack: give each live member its effective batch
    /// budget, run the whole pack with one launch pair per fleet
    /// iteration, and push one report per member — so a packed job's
    /// per-round report stream is exactly what it would produce
    /// standalone, just delivered every round instead of when picked.
    /// Allocation-free in the steady state.
    fn step_packs(&mut self) {
        let Session {
            batch_steps,
            ref mut packs,
            ref slots,
            ref mut rs,
            ..
        } = *self;
        for ps in packs.iter_mut().flatten() {
            let mut any = false;
            for (m, &idx) in ps.members.iter().enumerate() {
                if idx == usize::MAX {
                    continue;
                }
                let job = slots[idx].as_ref().expect("packed member is occupied");
                if job.stop.is_some() {
                    continue;
                }
                let k = effective_batch(batch_steps, &job.spec.termination, job.steps);
                ps.run.set_budget(m, k);
                any = true;
            }
            if !any {
                continue;
            }
            ps.run.step_budgeted();
            for (m, &idx) in ps.members.iter().enumerate() {
                if idx == usize::MAX {
                    continue;
                }
                if slots[idx].as_ref().expect("packed member is occupied").stop.is_some() {
                    continue;
                }
                rs.reports.push((idx, ps.run.member_report(m)));
            }
        }
    }

    /// Step every picked job once (a batch of `batch_steps` iterations),
    /// in parallel when the round holds several jobs — each job's
    /// launches go to its assigned pool stream, so the grids genuinely
    /// overlap. Suspended picks are restored first, onto the stream the
    /// round assigned them (migration when it differs from their last
    /// pinning). Leaves `(slot, report)` pairs sorted by slot index in
    /// `rs.reports`.
    ///
    /// Concurrent rounds default to the persistent executors (publish +
    /// wake per extra job); in spawn-per-round mode they fall back to one
    /// scoped OS thread per extra job — the legacy baseline
    /// `benches/scheduler_latency.rs` measures against.
    fn step_round(&mut self, clock: &mut PhaseClock) -> Result<()> {
        let Session {
            ref settings,
            batch_steps,
            ref mut slots,
            ref mut rs,
            ref executors,
            ..
        } = *self;
        for k in 0..rs.picked.len() {
            let (idx, stream) = rs.picked[k];
            let job = slots[idx].as_mut().expect("picked job is occupied");
            if job.run.is_none() {
                let ckpt = job.parked.take().expect("parked job has a checkpoint");
                // SAFETY: same slot-local erasure contract as `admit`.
                let fitness = unsafe { erased_fitness(&job.spec) };
                let run =
                    engine::restore_with(&ckpt, settings.clone().on_stream(stream), fitness)
                        .with_context(|| format!("restoring job {}", job.spec.name))?;
                job.run = Some(run);
                job.stream = stream;
                job.active_steps = 0;
            }
        }
        if rs.picked.is_empty() {
            // Every live job is packed this round; nothing standalone to
            // step. The split since pick covers the pack stepping.
            clock.lap(Series::RoundStepNs);
            return Ok(());
        }
        if let [(idx, _)] = *rs.picked {
            // Serialized fast path (always taken on a single-stream
            // pool): no stepping threads, identical to the pre-stream
            // scheduler loop.
            let job = slots[idx].as_mut().expect("picked job is occupied");
            let k = effective_batch(batch_steps, &job.spec.termination, job.steps);
            let run = job.run.as_mut().expect("picked job is active");
            rs.reports.push((idx, run.step_many(k)));
            clock.lap(Series::RoundStepNs);
            return Ok(());
        }
        if let Some(execs) = executors {
            // Persistent-executor path: publish every pick but the first
            // to an executor slot, step the first inline on the
            // scheduling thread, then collect the echoes — no spawn, no
            // join, no allocation.
            rs.inflight.clear();
            let mut first: Option<(usize, u64, &mut Box<dyn Run + 'static>)> = None;
            for (i, slot) in slots.iter_mut().enumerate() {
                let Some(job) = slot.as_mut() else { continue };
                if !rs.picked.iter().any(|&(p, _)| p == i) {
                    continue;
                }
                let k = effective_batch(batch_steps, &job.spec.termination, job.steps);
                let run = job.run.as_mut().expect("picked job is active");
                if first.is_none() {
                    first = Some((i, k, run));
                } else {
                    let e = rs.inflight.len();
                    // SAFETY: every submitted slot is waited on below,
                    // before the runs are touched again and before this
                    // function returns; each run goes to one slot.
                    unsafe { execs.submit(e, &mut **run, k) };
                    rs.inflight.push(i);
                }
            }
            clock.lap(Series::RoundPublishNs);
            // Anchor for per-executor wake-to-done latency: every wait
            // return below measures from the end of publication.
            let published = clock.mark();
            let (i0, k0, run0) = first.expect("non-empty round");
            rs.reports.push((i0, run0.step_many(k0)));
            clock.lap(Series::RoundStepNs);
            for (e, &i) in rs.inflight.iter().enumerate() {
                execs.wait(e);
                clock.record_since(published, Series::ExecWakeToDoneNs);
                rs.reports.push((i, execs.take_report(e)));
            }
            clock.lap(Series::RoundWakeNs);
        } else {
            // Legacy spawn-per-round path: S − 1 scoped threads per round.
            let tasks: Vec<(usize, u64, &mut SlotJob)> = slots
                .iter_mut()
                .enumerate()
                .filter_map(|(i, s)| s.as_mut().map(|job| (i, job)))
                .filter(|(i, _)| rs.picked.iter().any(|&(p, _)| p == *i))
                .map(|(i, job)| {
                    let k = effective_batch(batch_steps, &job.spec.termination, job.steps);
                    (i, k, job)
                })
                .collect();
            let stepped = std::thread::scope(|scope| {
                let mut it = tasks.into_iter();
                let (i0, k0, job0) = it.next().expect("non-empty round");
                let handles: Vec<_> = it
                    .map(|(i, k, job)| {
                        scope.spawn(move || {
                            let run = job.run.as_mut().expect("picked job is active");
                            (i, run.step_many(k))
                        })
                    })
                    .collect();
                // The scheduling thread steps the first job itself: a
                // round of S jobs costs S − 1 spawns.
                let run0 = job0.run.as_mut().expect("picked job is active");
                let mut out = vec![(i0, run0.step_many(k0))];
                for h in handles {
                    out.push(h.join().expect("stepping thread panicked"));
                }
                out
            });
            rs.reports.extend(stepped);
            clock.lap(Series::RoundStepNs);
        }
        Ok(())
    }

    /// Bring pack membership up to date at a round boundary (a single
    /// branch when nothing changed since the last round):
    ///
    /// 1. **Dissolve** any pack whose live membership fell below the
    ///    policy minimum — remaining members extract into parked
    ///    checkpoints and rejoin the standalone pool.
    /// 2. **Form** new packs from the unpacked live Queue jobs, grouped
    ///    by the compatibility key (dimensionality, objective), in slot
    ///    order, split into chunks of at most `pack_max` (0 =
    ///    unbounded). A chunk smaller than `pack_min` stays standalone —
    ///    including the leftover of a group that filled its packs, which
    ///    is exactly where a job admitted into a "full" pack lands.
    ///
    /// Formation moves each member's state into the shared slab (active
    /// runs suspend via `into_checkpoint`, which MOVES the swarm;
    /// parked jobs contribute their checkpoint `Arc`), so packs never
    /// deep-copy more than the one slab fill. Packs never grow after
    /// formation: later admissions group among themselves.
    fn reconcile_packs(&mut self) -> Result<()> {
        if !self.pack_dirty {
            return Ok(());
        }
        self.pack_dirty = false;
        let Some(policy) = self.pack_policy else {
            return Ok(());
        };
        // 1. Dissolve underfull packs.
        for p in 0..self.packs.len() {
            let underfull = self.packs[p]
                .as_ref()
                .is_some_and(|ps| ps.run.live_members() < policy.min);
            if !underfull {
                continue;
            }
            let mut ps = self.packs[p].take().expect("checked occupied");
            bump(Counter::PacksDissolved);
            trace(TraceKind::PackDissolve, p as u64, ps.run.live_members() as u64);
            for m in 0..ps.members.len() {
                let idx = ps.members[m];
                if idx == usize::MAX {
                    continue;
                }
                let job = self.slots[idx].as_mut().expect("packed member is occupied");
                job.parked = Some(Arc::new(ps.run.extract_member(m)));
                job.pack = None;
            }
        }
        // 2. Group the unpacked live Queue jobs by compatibility key.
        let mut candidates: Vec<(usize, u8, usize)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| {
                let job = s.as_ref()?;
                let eligible = job.stop.is_none()
                    && job.pack.is_none()
                    && !job.no_pack
                    && job.spec.engine == EngineKind::Queue;
                eligible.then(|| (job.spec.params.dim, objective_code(job.spec.objective), i))
            })
            .collect();
        candidates.sort_unstable();
        let mut lo = 0;
        while lo < candidates.len() {
            let key = (candidates[lo].0, candidates[lo].1);
            let mut hi = lo + 1;
            while hi < candidates.len() && (candidates[hi].0, candidates[hi].1) == key {
                hi += 1;
            }
            let group: Vec<usize> = candidates[lo..hi].iter().map(|&(_, _, i)| i).collect();
            lo = hi;
            let chunk_len = if policy.max == 0 {
                group.len()
            } else {
                policy.max
            };
            for chunk in group.chunks(chunk_len) {
                if chunk.len() >= policy.min {
                    self.form_pack(chunk)?;
                }
            }
        }
        Ok(())
    }

    /// Fuse the jobs in `chunk` (slot indices, all unpacked live Queue
    /// jobs of one compatibility group) into a new pack pinned to stream
    /// `pack slot % S`.
    fn form_pack(&mut self, chunk: &[usize]) -> Result<()> {
        let mut members_in: Vec<(Arc<RunCheckpoint>, Arc<dyn Fitness + Send>)> =
            Vec::with_capacity(chunk.len());
        for &idx in chunk {
            let job = self.slots[idx].as_mut().expect("candidate is occupied");
            let ckpt = match job.run.take() {
                Some(run) => Arc::new(run.into_checkpoint()),
                None => job
                    .parked
                    .take()
                    .expect("inactive job holds its checkpoint"),
            };
            members_in.push((ckpt, Arc::clone(&job.spec.fitness)));
        }
        let p = self
            .packs
            .iter()
            .position(Option::is_none)
            .unwrap_or(self.packs.len());
        let stream = p % self.streams;
        let run = match PackedRun::form(self.settings.clone().on_stream(stream), &members_in) {
            Ok(run) => run,
            Err(e) => {
                // Leave every would-be member parked on its checkpoint —
                // the session stays consistent, the jobs run standalone.
                for (&idx, (ckpt, _)) in chunk.iter().zip(members_in) {
                    let job = self.slots[idx].as_mut().expect("candidate is occupied");
                    job.parked = Some(ckpt);
                }
                return Err(e.context("forming a swarm pack"));
            }
        };
        for (m, &idx) in chunk.iter().enumerate() {
            let job = self.slots[idx].as_mut().expect("candidate is occupied");
            job.pack = Some((p, m));
            job.stream = stream;
            job.active_steps = 0;
        }
        let slot = PackSlot {
            run,
            members: chunk.to_vec(),
        };
        if p == self.packs.len() {
            self.packs.push(Some(slot));
        } else {
            self.packs[p] = Some(slot);
        }
        bump(Counter::PacksFormed);
        trace(TraceKind::PackForm, p as u64, chunk.len() as u64);
        Ok(())
    }

    /// Post-round pack maintenance: extract members that terminated this
    /// round (their outcome path runs off the parked checkpoint), and —
    /// when `preempting` carries the quantum — packed members that
    /// exhausted it. Preempted members set the sticky `no_pack` flag:
    /// they rejoin the *standalone* preemptive pool, where the ordinary
    /// pick/restore/suspend cycle time-shares them over the streams.
    fn sweep_packs(&mut self, preempting: Option<u64>) {
        let Session {
            ref mut packs,
            ref mut slots,
            ref mut pack_dirty,
            ..
        } = *self;
        for (p, pack) in packs.iter_mut().enumerate() {
            let Some(ps) = pack.as_mut() else { continue };
            for m in 0..ps.members.len() {
                let idx = ps.members[m];
                if idx == usize::MAX {
                    continue;
                }
                let job = slots[idx].as_mut().expect("packed member is occupied");
                let stopped = job.stop.is_some();
                let preempted = !stopped && preempting.is_some_and(|q| job.active_steps >= q);
                if !(stopped || preempted) {
                    continue;
                }
                job.parked = Some(Arc::new(ps.run.extract_member(m)));
                job.pack = None;
                job.no_pack |= preempted;
                ps.members[m] = usize::MAX;
                *pack_dirty = true;
            }
            if ps.run.live_members() == 0 {
                *pack = None;
                bump(Counter::PacksDissolved);
                trace(TraceKind::PackDissolve, p as u64, 0);
            }
        }
    }

    /// Extract every packed member back into a parked checkpoint — the
    /// drain/outcome path, where each job's state must stand alone.
    fn unpack_all(&mut self) {
        let Session {
            ref mut packs,
            ref mut slots,
            ref mut pack_dirty,
            ..
        } = *self;
        for (p, pack) in packs.iter_mut().enumerate() {
            let Some(ps) = pack.as_mut() else { continue };
            for m in 0..ps.members.len() {
                let idx = ps.members[m];
                if idx == usize::MAX {
                    continue;
                }
                let job = slots[idx].as_mut().expect("packed member is occupied");
                job.parked = Some(Arc::new(ps.run.extract_member(m)));
                job.pack = None;
                ps.members[m] = usize::MAX;
                *pack_dirty = true;
            }
            *pack = None;
            bump(Counter::PacksDissolved);
            trace(TraceKind::PackDissolve, p as u64, 0);
        }
    }
}

/// Total order for the pack-compatibility key ([`Objective`] itself
/// derives no `Ord`).
fn objective_code(objective: Objective) -> u8 {
    match objective {
        Objective::Maximize => 0,
        Objective::Minimize => 1,
    }
}

/// Deliver the round's reports: update progress/stall counters, evaluate
/// termination, and stream the [`JobReport`]s in slot order.
fn apply_reports<F: FnMut(&JobReport<'_>)>(
    slots: &mut [Option<SlotJob>],
    rs: &RoundState,
    live: &mut usize,
    telemetry: &mut F,
) {
    for (idx, report) in rs.reports.iter() {
        let idx = *idx;
        let job = slots[idx].as_mut().expect("reported job is occupied");
        let executed = report.iter - job.steps;
        job.steps = report.iter;
        job.active_steps += executed;
        if report.improved {
            job.stalled = 0;
        } else {
            job.stalled += executed;
        }
        // Criteria outrank budget exhaustion so a target hit on the
        // final iteration still reports TargetReached (matching the
        // precedence TerminationCriteria::check documents).
        let stop = job
            .spec
            .termination
            .check(job.spec.objective, report.gbest_fit, job.steps, job.stalled)
            .or(report.done.then_some(StopReason::Exhausted));
        telemetry(&JobReport {
            job: idx,
            name: &job.spec.name,
            iter: job.steps,
            gbest_fit: report.gbest_fit,
            improved: report.improved,
            finished: stop,
        });
        if stop.is_some() {
            job.stop = stop;
            *live -= 1;
        }
    }
}

/// Turn a terminated (or cancelled) slot into its [`JobOutcome`]. A job
/// that finished in a previous session (or was never reactivated) is
/// restored once, just to finish.
fn finish_slot(mut job: SlotJob, settings: &ParallelSettings, slot: usize) -> Result<JobOutcome> {
    let run = match job.run.take() {
        Some(run) => run,
        None => {
            let ckpt = job
                .parked
                .take()
                .expect("inactive job holds its checkpoint");
            // SAFETY: the restored run is consumed by `finish()` below,
            // before `job.spec` (and its fitness Arc) drops.
            let fitness = unsafe { erased_fitness(&job.spec) };
            engine::restore_with(&ckpt, settings.clone().on_stream(slot), fitness)
                .with_context(|| format!("finishing job {}", job.spec.name))?
        }
    };
    Ok(JobOutcome {
        name: job.spec.name.clone(),
        engine: job.spec.engine,
        stop: job.stop.expect("every finished job has a stop reason"),
        steps: job.steps,
        output: run.finish(),
    })
}

/// Up to `streams` live jobs, least-progressed first (ties → lowest
/// slot index), no two sharing a pool stream. This is the fair-share
/// generalization of one-step-each cycling to concurrent rounds: with a
/// single stream it degenerates to exactly the classic cyclic order (all
/// live jobs stay within one step of each other, and the least-stepped
/// lowest index is the next cyclic pick), while under stream conflicts
/// the lagging job of a contended stream always outranks its
/// stream-mates, so nobody starves. A freshly admitted job starts at
/// zero steps and therefore catches up with its neighbours first —
/// fair-share by progress, exactly as a fresh batch behaves.
fn pick_round_robin(slots: &[Option<SlotJob>], streams: usize, rs: &mut RoundState) {
    rs.order.clear();
    rs.order.extend(
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.as_ref().is_some_and(|j| j.stop.is_none() && j.pack.is_none()))
            .map(|(i, _)| i),
    );
    rs.order
        .sort_unstable_by_key(|&i| (slots[i].as_ref().expect("live slot").steps, i));
    assign_streams(slots, streams, rs);
}

/// Up to `streams` live jobs by ascending deadline slack (`deadline -
/// steps`; jobs without a deadline rank last, ties break on slot index so
/// scheduling is fully deterministic), no two sharing a pool stream.
fn pick_edf(slots: &[Option<SlotJob>], streams: usize, rs: &mut RoundState) {
    rs.order.clear();
    rs.order.extend(
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.as_ref().is_some_and(|j| j.stop.is_none() && j.pack.is_none()))
            .map(|(i, _)| i),
    );
    rs.order.sort_unstable_by_key(|&i| {
        let job = slots[i].as_ref().expect("live slot");
        let slack = job
            .spec
            .deadline
            .map(|d| d.saturating_sub(job.steps))
            .unwrap_or(u64::MAX);
        (slack, i)
    });
    assign_streams(slots, streams, rs);
}

/// Up to `streams` live jobs by ascending tenant step total (all live
/// jobs — packed ones too — charge steps to their tenant; jobs without a
/// tenant pool into one anonymous tenant), then own progress, then slot
/// index, no two sharing a pool stream. Round-robin fairness between
/// *tenants* rather than jobs: a tenant running ten jobs advances its
/// total ten times faster than a single-job tenant, so the single-job
/// tenant is picked every round while the heavy tenant's jobs share the
/// remaining streams — one noisy neighbour cannot starve the rest. The
/// per-tenant totals are recomputed each round into a [`RoundState`]
/// scratch buffer reserved at admission (an O(slots²) scan, negligible
/// next to a launch and allocation-free), so the pick is a pure function
/// of slot state and stays deterministic under any admission timing.
fn pick_weighted_fair(slots: &[Option<SlotJob>], streams: usize, rs: &mut RoundState) {
    rs.order.clear();
    rs.order.extend(
        slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.as_ref().is_some_and(|j| j.stop.is_none() && j.pack.is_none()))
            .map(|(i, _)| i),
    );
    rs.keys.clear();
    rs.keys.resize(slots.len(), 0);
    for i in 0..slots.len() {
        let Some(job) = slots[i].as_ref().filter(|j| j.stop.is_none()) else {
            continue;
        };
        let tenant = job.spec.tenant.as_deref();
        rs.keys[i] = slots
            .iter()
            .flatten()
            .filter(|j| j.stop.is_none() && j.spec.tenant.as_deref() == tenant)
            .map(|j| j.steps)
            .sum();
    }
    rs.order
        .sort_unstable_by_key(|&i| (rs.keys[i], slots[i].as_ref().expect("live slot").steps, i));
    assign_streams(slots, streams, rs);
}

/// Greedily assign the policy-ordered jobs (`rs.order`) to
/// pairwise-distinct streams, into `rs.picked` (one grid in flight per
/// stream per round). An active job keeps its pinning — its buffers
/// already target that stream — and is skipped if the stream is taken
/// this round; a suspended job has no pinning and takes the lowest free
/// stream (that restore-time re-pinning is the migration path). Fully
/// deterministic, and allocation-free: every buffer lives in
/// [`RoundState`].
fn assign_streams(slots: &[Option<SlotJob>], streams: usize, rs: &mut RoundState) {
    rs.used.iter_mut().for_each(|u| *u = false);
    rs.picked.clear();
    for &i in &rs.order {
        let job = slots[i].as_ref().expect("ordered slot is live");
        let stream = if job.run.is_some() {
            let s = job.stream;
            if rs.used[s] {
                continue;
            }
            s
        } else {
            match rs.used.iter().position(|&u| !u) {
                Some(s) => s,
                None => break,
            }
        };
        rs.used[stream] = true;
        rs.picked.push((i, stream));
        if rs.picked.len() == streams {
            break;
        }
    }
}
