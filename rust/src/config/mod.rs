//! Run configuration: a typed config struct, a TOML-subset file format,
//! and validation. serde is unavailable offline, so parsing is a small
//! hand-rolled scanner supporting the subset the launcher needs:
//! `[section]` headers, `key = value` with string / integer / float /
//! boolean values, `#` comments.

mod toml;

pub use toml::{parse_toml, toml_sections, TomlValue};

use crate::fitness::Objective;
use crate::rng::RngKind;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Which algorithm drives the swarm (the paper's five implementations,
/// plus the Plane-B XLA engines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Serial SPSO on one core (the paper's "CPU" column).
    SerialCpu,
    /// Parallel reduction, two kernels per iteration (state of the art).
    Reduction,
    /// Reduction with unrolled final levels ("Loop Unrolling").
    LoopUnrolling,
    /// Shared-memory queue (Algorithm 2) — the paper's contribution #1.
    Queue,
    /// Queue + global CAS lock, fused kernels (Algorithm 3) — contribution #2.
    QueueLock,
    /// Persistent-kernel fully asynchronous engine (the paper's §7 future
    /// work): one dispatch per run, blocks free-run all iterations.
    AsyncPersistent,
    /// Plane-B: AOT XLA artifact, synchronous coordinator.
    XlaSync,
    /// Plane-B: AOT XLA artifacts, asynchronous lock-based coordinator.
    XlaAsync,
}

impl EngineKind {
    /// All Plane-A engines in the paper's Table 3 column order.
    pub const TABLE3: [EngineKind; 5] = [
        EngineKind::SerialCpu,
        EngineKind::Reduction,
        EngineKind::LoopUnrolling,
        EngineKind::Queue,
        EngineKind::QueueLock,
    ];

    /// Parse CLI/config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "serial" | "cpu" | "serialcpu" => Some(Self::SerialCpu),
            "reduction" => Some(Self::Reduction),
            "unroll" | "loopunrolling" | "unrolling" => Some(Self::LoopUnrolling),
            "queue" => Some(Self::Queue),
            "queuelock" => Some(Self::QueueLock),
            "async" | "asyncpersistent" | "persistent" => Some(Self::AsyncPersistent),
            "xla" | "xlasync" => Some(Self::XlaSync),
            "xlaasync" => Some(Self::XlaAsync),
            _ => None,
        }
    }

    /// Whether this kind runs on the Plane-A thread substrate (and is
    /// therefore schedulable by [`crate::scheduler::JobScheduler`]).
    pub fn is_plane_a(self) -> bool {
        !matches!(self, Self::XlaSync | Self::XlaAsync)
    }

    /// Table-header label (matches the paper's column names).
    pub fn label(&self) -> &'static str {
        match self {
            Self::SerialCpu => "CPU",
            Self::Reduction => "Reduction",
            Self::LoopUnrolling => "Loop Unrolling",
            Self::Queue => "Queue",
            Self::QueueLock => "Queue Lock",
            Self::AsyncPersistent => "Async Persistent",
            Self::XlaSync => "XLA Sync",
            Self::XlaAsync => "XLA Async",
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// Full run configuration for the launcher.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Fitness function name (see [`crate::fitness::by_name`]).
    pub fitness: String,
    /// Optimization sense; `None` = the function's conventional default.
    pub objective: Option<Objective>,
    /// Swarm size (`particle_cnt`).
    pub particles: usize,
    /// Problem dimensionality.
    pub dim: usize,
    /// Iteration budget (`max_iter`).
    pub iters: u64,
    /// Inertia weight `w` (paper: 1.0).
    pub w: f64,
    /// Cognitive coefficient `c1` (paper: 2.0).
    pub c1: f64,
    /// Social coefficient `c2` (paper: 2.0).
    pub c2: f64,
    /// Position bounds override; `None` = the function's domain.
    pub bounds: Option<(f64, f64)>,
    /// Velocity clamp as a fraction of the position range (common PSO
    /// practice; the paper clamps velocity to a fixed range).
    pub vmax_frac: f64,
    /// Engine selection.
    pub engine: EngineKind,
    /// Worker threads for the parallel engines (0 = machine default).
    pub workers: usize,
    /// RNG engine (§5.4 ablation).
    pub rng: RngKind,
    /// Master seed.
    pub seed: u64,
    /// Directory of AOT artifacts (Plane-B engines).
    pub artifacts_dir: String,
    /// Shards for the XLA coordinator.
    pub shards: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            fitness: "cubic".into(),
            objective: None,
            particles: 1024,
            dim: 1,
            iters: 10_000,
            w: 1.0,
            c1: 2.0,
            c2: 2.0,
            bounds: None,
            vmax_frac: 0.5,
            engine: EngineKind::QueueLock,
            workers: 0,
            rng: RngKind::Philox,
            seed: 42,
            artifacts_dir: "artifacts".into(),
            shards: 4,
        }
    }
}

impl RunConfig {
    /// Load from a TOML-subset file (flat keys or under `[pso]`/`[run]`).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    /// Parse from TOML-subset text.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = Self::default();
        // Accept both flat keys and any section; last write wins.
        let mut flat: BTreeMap<String, TomlValue> = BTreeMap::new();
        for (key, value) in doc {
            let leaf = key.rsplit('.').next().unwrap().to_string();
            flat.insert(leaf, value);
        }
        macro_rules! get {
            ($name:literal, $conv:ident) => {
                flat.get($name).map(|v| v.$conv($name)).transpose()?
            };
        }
        if let Some(v) = get!("fitness", as_str) {
            cfg.fitness = v.to_string();
        }
        if let Some(v) = get!("objective", as_str) {
            cfg.objective =
                Some(Objective::parse(v).with_context(|| format!("bad objective {v}"))?);
        }
        if let Some(v) = get!("particles", as_int) {
            cfg.particles = v as usize;
        }
        if let Some(v) = get!("dim", as_int) {
            cfg.dim = v as usize;
        }
        if let Some(v) = get!("iters", as_int) {
            cfg.iters = v as u64;
        }
        if let Some(v) = get!("w", as_float) {
            cfg.w = v;
        }
        if let Some(v) = get!("c1", as_float) {
            cfg.c1 = v;
        }
        if let Some(v) = get!("c2", as_float) {
            cfg.c2 = v;
        }
        if let (Some(lo), Some(hi)) = (get!("min_pos", as_float), get!("max_pos", as_float)) {
            cfg.bounds = Some((lo, hi));
        }
        if let Some(v) = get!("vmax_frac", as_float) {
            cfg.vmax_frac = v;
        }
        if let Some(v) = get!("engine", as_str) {
            cfg.engine = EngineKind::parse(v).with_context(|| format!("bad engine {v}"))?;
        }
        if let Some(v) = get!("workers", as_int) {
            cfg.workers = v as usize;
        }
        if let Some(v) = get!("rng", as_str) {
            cfg.rng = RngKind::parse(v).with_context(|| format!("bad rng {v}"))?;
        }
        if let Some(v) = get!("seed", as_int) {
            cfg.seed = v as u64;
        }
        if let Some(v) = get!("artifacts_dir", as_str) {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = get!("shards", as_int) {
            cfg.shards = v as usize;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check ranges.
    pub fn validate(&self) -> Result<()> {
        if self.particles == 0 {
            bail!("particles must be > 0");
        }
        if self.dim == 0 {
            bail!("dim must be > 0");
        }
        if self.iters == 0 {
            bail!("iters must be > 0");
        }
        if !(self.w.is_finite() && self.c1.is_finite() && self.c2.is_finite()) {
            bail!("non-finite PSO coefficients");
        }
        if let Some((lo, hi)) = self.bounds {
            if !(lo < hi) {
                bail!("bounds must satisfy min < max, got [{lo}, {hi}]");
            }
        }
        if !(0.0 < self.vmax_frac && self.vmax_frac <= 1.0) {
            bail!("vmax_frac must be in (0, 1], got {}", self.vmax_frac);
        }
        if crate::fitness::by_name(&self.fitness).is_none() {
            bail!("unknown fitness function '{}'", self.fitness);
        }
        if self.shards == 0 {
            bail!("shards must be > 0");
        }
        Ok(())
    }
}

/// One job entry of a multi-job batch file (a `[jobs.<name>]` section).
#[derive(Debug, Clone, PartialEq)]
pub struct JobConfig {
    /// Section name (job identifier in reports).
    pub name: String,
    /// Fitness function name.
    pub fitness: String,
    /// Optimization sense; `None` = the function's convention.
    pub objective: Option<Objective>,
    /// Swarm size.
    pub particles: usize,
    /// Dimensionality.
    pub dim: usize,
    /// Iteration budget (`max_iter` of the run).
    pub iters: u64,
    /// Engine kind (Plane-A only).
    pub engine: EngineKind,
    /// Velocity clamp as a fraction of the position range.
    pub vmax_frac: f64,
    /// Master seed.
    pub seed: u64,
    /// Early stop: target fitness.
    pub target_fitness: Option<f64>,
    /// Early stop: consecutive non-improving steps.
    pub stall_window: Option<u64>,
    /// Early stop: scheduler-step cap (below `iters`).
    pub max_steps: Option<u64>,
    /// EDF deadline in scheduler steps.
    pub deadline: Option<u64>,
    /// Owning tenant for service quotas and weighted-fair scheduling;
    /// `None` = the anonymous tenant.
    pub tenant: Option<String>,
}

impl JobConfig {
    /// An all-defaults job named `name` (the starting point for a bare
    /// `[jobs.<name>]` section, a service `submit` request, and the
    /// `cupso submit` flag parser).
    pub fn with_defaults(name: &str) -> Self {
        Self {
            name: name.to_string(),
            fitness: "cubic".into(),
            objective: None,
            particles: 1024,
            dim: 1,
            iters: 1000,
            engine: EngineKind::QueueLock,
            vmax_frac: 0.5,
            seed: 42,
            target_fitness: None,
            stall_window: None,
            max_steps: None,
            deadline: None,
            tenant: None,
        }
    }

    /// Sanity-check ranges.
    pub fn validate(&self) -> Result<()> {
        if self.particles == 0 {
            bail!("job {}: particles must be > 0", self.name);
        }
        if self.dim == 0 {
            bail!("job {}: dim must be > 0", self.name);
        }
        if self.iters == 0 {
            bail!("job {}: iters must be > 0", self.name);
        }
        if crate::fitness::by_name(&self.fitness).is_none() {
            bail!("job {}: unknown fitness '{}'", self.name, self.fitness);
        }
        if !(0.0 < self.vmax_frac && self.vmax_frac <= 1.0) {
            bail!(
                "job {}: vmax_frac must be in (0, 1], got {}",
                self.name,
                self.vmax_frac
            );
        }
        if !self.engine.is_plane_a() {
            bail!(
                "job {}: engine {} is not schedulable (Plane-A only)",
                self.name,
                self.engine
            );
        }
        if self.stall_window == Some(0) {
            bail!("job {}: stall_window must be > 0", self.name);
        }
        if self.max_steps == Some(0) {
            bail!("job {}: max_steps must be > 0", self.name);
        }
        if self.tenant.as_deref() == Some("") {
            bail!("job {}: tenant must be a non-empty string", self.name);
        }
        Ok(())
    }
}

/// A multi-job batch configuration: `[scheduler]` knobs plus one
/// `[jobs.<name>]` section per job, in file order.
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Worker threads for the one shared pool (0 = machine default).
    pub workers: usize,
    /// Stepping policy name (`round-robin` | `edf` | `weighted-fair`).
    pub policy: String,
    /// Concurrent pool streams: up to this many jobs step in parallel
    /// per scheduling round (1 = the serialized scheduler).
    pub streams: usize,
    /// Iterations per job per scheduling round (1 = step-at-a-time).
    pub batch_steps: u64,
    /// Preemption quantum in steps: when jobs outnumber streams, a job
    /// that ran this many steps since activation is suspended to a
    /// checkpoint and later restored on a free stream (0 = cooperative
    /// scheduling, the default).
    pub preempt_quantum: u64,
    /// Swarm-packing: group compatible live Queue jobs into one shared
    /// slab stepped with a single launch pair per round (off by default;
    /// see [`crate::scheduler::JobScheduler::pack`]).
    pub pack: bool,
    /// Smallest compatible group worth packing (≥ 2).
    pub pack_min: usize,
    /// Largest pack formed (0 = unbounded).
    pub pack_max: usize,
    /// Service admission quota: max concurrently live jobs per tenant
    /// (0 = unlimited). Enforced by `ServiceSession` at `submit` time.
    pub quota_jobs: usize,
    /// Service admission quota: max outstanding iteration budget per
    /// tenant, summed over its live jobs (0 = unlimited).
    pub quota_steps: u64,
    /// Periodic persistence cadence in scheduling rounds: with a
    /// checkpoint directory configured, snapshot every N round
    /// boundaries while running (batch and serve alike). 0 = only at
    /// explicit points (suspend, drain). CLI `--checkpoint-every`
    /// overrides.
    pub checkpoint_every: u64,
    /// Snapshot retention: how many rotated snapshots survive pruning
    /// (1 = overwrite the directory in place). CLI `--checkpoint-keep`
    /// overrides.
    pub checkpoint_keep: usize,
    /// Runtime telemetry (the [`crate::telemetry`] registry + flight
    /// recorder): on by default — recording is a handful of relaxed
    /// atomics per round. `false` turns every recording call into an
    /// early-out, which exists to *prove* invisibility (determinism
    /// tier diffs on vs. off), not to save cost.
    pub telemetry: bool,
    /// File the flight-recorder trace ring is appended to on panic,
    /// fatal persist failure, or drain. `None` = stderr. CLI
    /// `--trace-dump` overrides.
    pub trace_dump: Option<String>,
    /// The jobs, in file order.
    pub jobs: Vec<JobConfig>,
}

/// Coerce a TOML integer to u64, rejecting negatives (a plain `as u64`
/// would wrap a config typo like `particles = -1` into 1.8e19 and blow
/// past `validate()` into an allocation abort).
fn as_uint(value: &TomlValue, ctx: &str) -> Result<u64> {
    let v = value.as_int(ctx)?;
    if v < 0 {
        bail!("{ctx}: must be non-negative, got {v}");
    }
    Ok(v as u64)
}

impl BatchConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading batch config {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    /// Load for `cupso serve`: identical parsing and validation except
    /// that a file with zero `[jobs.<name>]` sections is legal — a
    /// daemon's jobs may all arrive live via `submit`, so a
    /// scheduler-knobs-only config is a perfectly sensible service
    /// seed (it is a batch-file error, where no jobs means no work).
    pub fn from_file_for_service(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading service config {}", path.display()))?;
        let cfg = Self::from_toml_str_with(&text, false)?;
        Ok(cfg)
    }

    /// Parse from TOML-subset text.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        Self::from_toml_str_with(text, true)
    }

    fn from_toml_str_with(text: &str, require_jobs: bool) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = Self {
            workers: 0,
            policy: "round-robin".into(),
            streams: 1,
            batch_steps: 1,
            preempt_quantum: 0,
            pack: false,
            pack_min: 2,
            pack_max: 0,
            quota_jobs: 0,
            quota_steps: 0,
            checkpoint_every: 0,
            checkpoint_keep: 1,
            telemetry: true,
            trace_dump: None,
            jobs: Vec::new(),
        };
        // Materialize a job per `[jobs.<name>]` section header first, so a
        // section with no overrides still declares an all-defaults job.
        for section in toml_sections(text)? {
            if let Some(name) = section.strip_prefix("jobs.") {
                if name.is_empty() || name.contains('.') {
                    bail!("bad job section [{section}]: job names must be a single segment");
                }
                // Names are identity keys (the scheduler and the service
                // address jobs by name): a repeated section used to merge
                // silently, with later fields shadowing earlier ones.
                if cfg.jobs.iter().any(|j| j.name == name) {
                    bail!(
                        "duplicate job section [jobs.{name}]: job names are unique identity keys"
                    );
                }
                cfg.jobs.push(JobConfig::with_defaults(name));
            }
        }
        for (key, value) in doc {
            if let Some(rest) = key.strip_prefix("jobs.") {
                // split_once (not rsplit): a dotted section like
                // [jobs.alpha.limits] must surface as an unknown field of
                // job "alpha", not materialize a phantom "alpha.limits" job.
                let Some((name, field)) = rest.split_once('.') else {
                    bail!("batch key {key:?}: expected [jobs.<name>] sections");
                };
                let idx = match cfg.jobs.iter().position(|j| j.name == name) {
                    Some(i) => i,
                    None => {
                        cfg.jobs.push(JobConfig::with_defaults(name));
                        cfg.jobs.len() - 1
                    }
                };
                let job = &mut cfg.jobs[idx];
                let ctx = format!("jobs.{name}.{field}");
                match field {
                    "fitness" => job.fitness = value.as_str(&ctx)?.to_string(),
                    "objective" => {
                        let v = value.as_str(&ctx)?;
                        job.objective = Some(
                            Objective::parse(v).with_context(|| format!("bad objective {v}"))?,
                        );
                    }
                    "particles" => job.particles = as_uint(&value, &ctx)? as usize,
                    "dim" => job.dim = as_uint(&value, &ctx)? as usize,
                    "iters" => job.iters = as_uint(&value, &ctx)?,
                    "engine" => {
                        let v = value.as_str(&ctx)?;
                        job.engine =
                            EngineKind::parse(v).with_context(|| format!("bad engine {v}"))?;
                    }
                    "seed" => job.seed = as_uint(&value, &ctx)?,
                    "vmax_frac" => job.vmax_frac = value.as_float(&ctx)?,
                    "target_fitness" => job.target_fitness = Some(value.as_float(&ctx)?),
                    "stall_window" => job.stall_window = Some(as_uint(&value, &ctx)?),
                    "max_steps" => job.max_steps = Some(as_uint(&value, &ctx)?),
                    "deadline" => job.deadline = Some(as_uint(&value, &ctx)?),
                    "tenant" => job.tenant = Some(value.as_str(&ctx)?.to_string()),
                    other => bail!("jobs.{name}: unknown field {other:?}"),
                }
            } else {
                // Scheduler-level knobs: flat keys or under [scheduler]
                // only — other sections must not silently reconfigure the
                // pool.
                let (section, field) = match key.rsplit_once('.') {
                    Some((s, f)) => (s, f),
                    None => ("", key.as_str()),
                };
                if !(section.is_empty() || section == "scheduler") {
                    bail!("unknown batch section {section:?} (key {key:?})");
                }
                match field {
                    "workers" => cfg.workers = as_uint(&value, &key)? as usize,
                    "policy" => cfg.policy = value.as_str(&key)?.to_string(),
                    "streams" => cfg.streams = as_uint(&value, &key)? as usize,
                    "batch_steps" => cfg.batch_steps = as_uint(&value, &key)?,
                    "preempt_quantum" => cfg.preempt_quantum = as_uint(&value, &key)?,
                    "pack" => cfg.pack = value.as_bool(&key)?,
                    "pack_min" => cfg.pack_min = as_uint(&value, &key)? as usize,
                    "pack_max" => cfg.pack_max = as_uint(&value, &key)? as usize,
                    "quota_jobs" => cfg.quota_jobs = as_uint(&value, &key)? as usize,
                    "quota_steps" => cfg.quota_steps = as_uint(&value, &key)?,
                    "checkpoint_every" => cfg.checkpoint_every = as_uint(&value, &key)?,
                    "checkpoint_keep" => cfg.checkpoint_keep = as_uint(&value, &key)? as usize,
                    "telemetry" => cfg.telemetry = value.as_bool(&key)?,
                    "trace_dump" => cfg.trace_dump = Some(value.as_str(&key)?.to_string()),
                    other => bail!("unknown batch key {other:?} (in {key:?})"),
                }
            }
        }
        if require_jobs && cfg.jobs.is_empty() {
            bail!("batch config declares no [jobs.<name>] sections");
        }
        cfg.validate_allowing_no_jobs()?;
        Ok(cfg)
    }

    /// Sanity-check the batch as a whole (a batch without jobs is an
    /// error; the service path uses [`from_file_for_service`](Self::from_file_for_service)).
    pub fn validate(&self) -> Result<()> {
        if self.jobs.is_empty() {
            bail!("batch config declares no [jobs.<name>] sections");
        }
        self.validate_allowing_no_jobs()
    }

    /// The knob and per-job checks shared by the batch and service
    /// intake paths.
    fn validate_allowing_no_jobs(&self) -> Result<()> {
        if crate::scheduler::SchedPolicy::parse(&self.policy).is_none() {
            bail!("bad policy {:?} (round-robin|edf|weighted-fair)", self.policy);
        }
        if self.streams == 0 {
            bail!("streams must be >= 1");
        }
        if self.batch_steps == 0 {
            bail!("batch_steps must be >= 1");
        }
        if self.pack_min < 2 {
            bail!("pack_min must be >= 2 (a pack of one is a standalone job)");
        }
        if self.pack_max != 0 && self.pack_max < self.pack_min {
            bail!(
                "pack_max ({}) must be 0 (unbounded) or >= pack_min ({})",
                self.pack_max,
                self.pack_min
            );
        }
        if self.checkpoint_keep == 0 {
            bail!("checkpoint_keep must be >= 1");
        }
        for (i, job) in self.jobs.iter().enumerate() {
            job.validate()?;
            // Defense in depth for programmatic construction — the TOML
            // path already rejects repeated [jobs.<name>] sections.
            if self.jobs[..i].iter().any(|j| j.name == job.name) {
                bail!(
                    "duplicate job name {:?}: job names are unique identity keys",
                    job.name
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_paperlike() {
        let c = RunConfig::default();
        c.validate().unwrap();
        assert_eq!(c.w, 1.0);
        assert_eq!(c.c1, 2.0);
        assert_eq!(c.c2, 2.0);
        assert_eq!(c.fitness, "cubic");
    }

    #[test]
    fn parses_flat_and_sectioned_toml() {
        let cfg = RunConfig::from_toml_str(
            r#"
            # paper 120D workload
            [pso]
            fitness = "cubic"
            particles = 32768
            dim = 120
            iters = 1000
            [run]
            engine = "queue"
            workers = 8
            rng = "philox"
            seed = 7
            "#,
        )
        .unwrap();
        assert_eq!(cfg.particles, 32_768);
        assert_eq!(cfg.dim, 120);
        assert_eq!(cfg.engine, EngineKind::Queue);
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::from_toml_str("particles = 0").is_err());
        assert!(RunConfig::from_toml_str("engine = \"warp\"").is_err());
        assert!(RunConfig::from_toml_str("fitness = \"nope\"").is_err());
        assert!(
            RunConfig::from_toml_str("min_pos = 5.0\nmax_pos = -5.0").is_err()
        );
    }

    #[test]
    fn batch_config_parses_jobs_in_order() {
        let cfg = BatchConfig::from_toml_str(
            r#"
            [scheduler]
            workers = 4
            policy = "edf"

            [jobs.alpha]
            fitness = "cubic"
            engine = "queue"
            particles = 256
            iters = 500
            seed = 1
            target_fitness = 899_000.0
            deadline = 500

            [jobs.beta]
            fitness = "sphere"
            engine = "reduction"
            particles = 128
            dim = 3
            iters = 300
            seed = 2
            stall_window = 50
            "#,
        )
        .unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.policy, "edf");
        assert_eq!(cfg.jobs.len(), 2);
        let a = &cfg.jobs[0];
        assert_eq!(a.name, "alpha");
        assert_eq!(a.engine, EngineKind::Queue);
        assert_eq!(a.target_fitness, Some(899_000.0));
        assert_eq!(a.deadline, Some(500));
        assert_eq!(a.dim, 1, "default dim");
        let b = &cfg.jobs[1];
        assert_eq!(b.name, "beta");
        assert_eq!(b.fitness, "sphere");
        assert_eq!(b.dim, 3);
        assert_eq!(b.stall_window, Some(50));
        assert_eq!(b.target_fitness, None);
    }

    #[test]
    fn batch_config_parses_scheduler_knobs_and_vmax_frac() {
        let cfg = BatchConfig::from_toml_str(
            r#"
            [scheduler]
            workers = 8
            streams = 4
            batch_steps = 16

            [jobs.a]
            seed = 1
            vmax_frac = 0.1
            [jobs.b]
            seed = 2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.streams, 4);
        assert_eq!(cfg.batch_steps, 16);
        assert_eq!(cfg.preempt_quantum, 0, "preemption defaults off");
        let preemptive =
            BatchConfig::from_toml_str("preempt_quantum = 8\n[jobs.x]\nseed = 1").unwrap();
        assert_eq!(preemptive.preempt_quantum, 8);
        assert_eq!(cfg.jobs[0].vmax_frac, 0.1);
        assert_eq!(cfg.jobs[1].vmax_frac, 0.5, "default preserved");
        // Defaults when the keys are absent: the serialized scheduler.
        let plain = BatchConfig::from_toml_str("[jobs.x]\nseed = 1").unwrap();
        assert_eq!(plain.streams, 1);
        assert_eq!(plain.batch_steps, 1);
        // Out-of-range values are load-time errors.
        assert!(BatchConfig::from_toml_str("streams = 0\n[jobs.x]\nseed = 1").is_err());
        assert!(BatchConfig::from_toml_str("batch_steps = 0\n[jobs.x]\nseed = 1").is_err());
        assert!(BatchConfig::from_toml_str("[jobs.x]\nvmax_frac = 0.0").is_err());
        assert!(BatchConfig::from_toml_str("[jobs.x]\nvmax_frac = 1.5").is_err());
    }

    #[test]
    fn batch_config_parses_pack_knobs() {
        let cfg = BatchConfig::from_toml_str(
            "[scheduler]\npack = true\npack_min = 4\npack_max = 32\n[jobs.x]\nseed = 1",
        )
        .unwrap();
        assert!(cfg.pack);
        assert_eq!(cfg.pack_min, 4);
        assert_eq!(cfg.pack_max, 32);
        // Defaults: packing off, min 2, unbounded max.
        let plain = BatchConfig::from_toml_str("[jobs.x]\nseed = 1").unwrap();
        assert!(!plain.pack);
        assert_eq!(plain.pack_min, 2);
        assert_eq!(plain.pack_max, 0);
        // Out-of-range values are load-time errors.
        assert!(BatchConfig::from_toml_str("pack_min = 1\n[jobs.x]\nseed = 1").is_err());
        assert!(
            BatchConfig::from_toml_str("pack_min = 8\npack_max = 4\n[jobs.x]\nseed = 1").is_err()
        );
        assert!(BatchConfig::from_toml_str("pack = 1\n[jobs.x]\nseed = 1").is_err(), "not a bool");
    }

    #[test]
    fn batch_config_rejects_bad_input() {
        assert!(BatchConfig::from_toml_str("workers = 2").is_err(), "no jobs");
        assert!(BatchConfig::from_toml_str("[jobs.x]\nengine = \"xla\"").is_err());
        assert!(BatchConfig::from_toml_str("[jobs.x]\nparticles = 0").is_err());
        assert!(BatchConfig::from_toml_str("[jobs.x]\nnope = 1").is_err());
        assert!(BatchConfig::from_toml_str("[jobs.x]\nfitness = \"warp\"").is_err());
        // Negative integers must be rejected, not wrapped.
        assert!(BatchConfig::from_toml_str("[jobs.x]\nparticles = -1").is_err());
        assert!(BatchConfig::from_toml_str("[jobs.x]\nseed = -7").is_err());
        // Scheduler knobs only live at top level or under [scheduler].
        assert!(BatchConfig::from_toml_str("[metadata]\nworkers = 1\n[jobs.x]\nseed = 1").is_err());
        // Dotted job sections are typos, not phantom jobs.
        assert!(BatchConfig::from_toml_str("[jobs.x.limits]\nmax_steps = 100").is_err());
        // A repeated [jobs.<name>] section used to merge silently (later
        // fields shadowing earlier ones); names are identity keys now.
        let err = BatchConfig::from_toml_str("[jobs.x]\nseed = 1\n[jobs.x]\nseed = 2")
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate job section"), "{err}");
        // Unknown policy is a load-time error, not a CLI-only one.
        assert!(BatchConfig::from_toml_str("policy = \"fifo\"\n[jobs.x]\nseed = 1").is_err());
        // A valid minimal job fills every default.
        let cfg = BatchConfig::from_toml_str("[jobs.x]\nseed = 9").unwrap();
        assert_eq!(cfg.jobs[0].engine, EngineKind::QueueLock);
        assert_eq!(cfg.jobs[0].seed, 9);
    }

    #[test]
    fn service_config_may_omit_jobs_but_batch_may_not() {
        let knobs_only = "[scheduler]\nworkers = 2\nstreams = 4\nbatch_steps = 8\n";
        // Batch path: no jobs = no work = error.
        assert!(BatchConfig::from_toml_str(knobs_only).is_err());
        // Service path: jobs arrive live; the knobs must load fine.
        let dir = std::env::temp_dir().join("cupso-service-cfg-unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("knobs.toml");
        std::fs::write(&path, knobs_only).unwrap();
        let cfg = BatchConfig::from_file_for_service(&path).unwrap();
        assert_eq!(cfg.streams, 4);
        assert_eq!(cfg.batch_steps, 8);
        assert!(cfg.jobs.is_empty());
        // Bad knobs still fail loudly on the service path.
        std::fs::write(&path, "[scheduler]\nstreams = 0\n").unwrap();
        assert!(BatchConfig::from_file_for_service(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batch_config_parses_tenants_and_quota_knobs() {
        let cfg = BatchConfig::from_toml_str(
            r#"
            [scheduler]
            policy = "weighted-fair"
            quota_jobs = 4
            quota_steps = 100_000

            [jobs.a]
            seed = 1
            tenant = "team-a"
            [jobs.b]
            seed = 2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.policy, "weighted-fair");
        assert_eq!(cfg.quota_jobs, 4);
        assert_eq!(cfg.quota_steps, 100_000);
        assert_eq!(cfg.jobs[0].tenant.as_deref(), Some("team-a"));
        assert_eq!(cfg.jobs[1].tenant, None, "tenant defaults to anonymous");
        // Defaults: quotas off.
        let plain = BatchConfig::from_toml_str("[jobs.x]\nseed = 1").unwrap();
        assert_eq!(plain.quota_jobs, 0);
        assert_eq!(plain.quota_steps, 0);
        // Out-of-range values are load-time errors.
        assert!(BatchConfig::from_toml_str("quota_jobs = -1\n[jobs.x]\nseed = 1").is_err());
        assert!(BatchConfig::from_toml_str("[jobs.x]\ntenant = \"\"").is_err(), "empty tenant");
        assert!(BatchConfig::from_toml_str("[jobs.x]\ntenant = 3").is_err(), "not a string");
    }

    #[test]
    fn batch_config_parses_telemetry_knobs() {
        let cfg = BatchConfig::from_toml_str(
            "[scheduler]\ntelemetry = false\ntrace_dump = \"/tmp/trace.log\"\n[jobs.x]\nseed = 1",
        )
        .unwrap();
        assert!(!cfg.telemetry);
        assert_eq!(cfg.trace_dump.as_deref(), Some("/tmp/trace.log"));
        // Defaults: telemetry on, trace ring dumps to stderr.
        let plain = BatchConfig::from_toml_str("[jobs.x]\nseed = 1").unwrap();
        assert!(plain.telemetry);
        assert_eq!(plain.trace_dump, None);
        // Type errors are load-time errors.
        assert!(BatchConfig::from_toml_str("telemetry = 1\n[jobs.x]\nseed = 1").is_err());
        assert!(BatchConfig::from_toml_str("trace_dump = 3\n[jobs.x]\nseed = 1").is_err());
    }

    #[test]
    fn batch_config_keeps_empty_job_sections() {
        // A bare [jobs.<name>] header with no overrides is still a job.
        let cfg = BatchConfig::from_toml_str("[jobs.defaults]\n[jobs.tuned]\nseed = 3").unwrap();
        assert_eq!(cfg.jobs.len(), 2);
        assert_eq!(cfg.jobs[0].name, "defaults");
        assert_eq!(cfg.jobs[0].seed, 42);
        assert_eq!(cfg.jobs[1].name, "tuned");
        assert_eq!(cfg.jobs[1].seed, 3);
    }

    #[test]
    fn engine_kind_is_plane_a() {
        for k in EngineKind::TABLE3 {
            assert!(k.is_plane_a());
        }
        assert!(EngineKind::AsyncPersistent.is_plane_a());
        assert!(!EngineKind::XlaSync.is_plane_a());
        assert!(!EngineKind::XlaAsync.is_plane_a());
    }

    #[test]
    fn engine_kind_parse_labels() {
        for k in EngineKind::TABLE3 {
            // label → parse round trip (modulo spaces/case).
            let norm = k.label().replace(' ', "").to_lowercase();
            assert_eq!(EngineKind::parse(&norm), Some(k), "{norm}");
        }
        assert_eq!(EngineKind::parse("xla-async"), Some(EngineKind::XlaAsync));
    }
}
