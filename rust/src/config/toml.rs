//! TOML-subset parser: `[section]` headers, `key = value` pairs, `#`
//! comments. Values: quoted strings, booleans, integers (with `_`
//! separators), floats. Keys are returned dotted (`section.key`).

use anyhow::{bail, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    /// Coerce to string.
    pub fn as_str(&self, key: &str) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("{key}: expected string, got {other:?}"),
        }
    }

    /// Coerce to integer.
    pub fn as_int(&self, key: &str) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            other => bail!("{key}: expected integer, got {other:?}"),
        }
    }

    /// Coerce to float (integers widen).
    pub fn as_float(&self, key: &str) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("{key}: expected float, got {other:?}"),
        }
    }

    /// Coerce to bool.
    pub fn as_bool(&self, key: &str) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("{key}: expected bool, got {other:?}"),
        }
    }
}

/// Parse the subset; returns `(dotted_key, value)` pairs in file order.
pub fn parse_toml(text: &str) -> Result<Vec<(String, TomlValue)>> {
    let mut section = String::new();
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            section = name.trim().to_string();
            if section.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected `key = value`, got {line:?}", lineno + 1);
        };
        let key = line[..eq].trim();
        let val = line[eq + 1..].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let dotted = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        out.push((dotted, parse_value(val, lineno + 1)?));
    }
    Ok(out)
}

/// List the `[section]` names of the subset, in file order (including
/// sections with no keys — `parse_toml` cannot surface those, and the
/// batch config needs them so an empty `[jobs.x]` still declares a job).
pub fn toml_sections(text: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            let name = name.trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            out.push(name.to_string());
        }
    }
    Ok(out)
}

/// Remove a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<TomlValue> {
    if v.is_empty() {
        bail!("line {lineno}: missing value");
    }
    if let Some(inner) = v.strip_prefix('"') {
        let Some(s) = inner.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string {v:?}");
        };
        return Ok(TomlValue::Str(s.to_string()));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {lineno}: cannot parse value {v:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_scalar_kinds() {
        let doc = parse_toml(
            r#"
            name = "cubic"       # trailing comment
            particles = 65_536
            w = 1.0
            fused = true
            neg = -3
            sci = 1.5e3
            "#,
        )
        .unwrap();
        let get = |k: &str| doc.iter().find(|(key, _)| key == k).unwrap().1.clone();
        assert_eq!(get("name"), TomlValue::Str("cubic".into()));
        assert_eq!(get("particles"), TomlValue::Int(65_536));
        assert_eq!(get("w"), TomlValue::Float(1.0));
        assert_eq!(get("fused"), TomlValue::Bool(true));
        assert_eq!(get("neg"), TomlValue::Int(-3));
        assert_eq!(get("sci"), TomlValue::Float(1500.0));
    }

    #[test]
    fn sections_dot_the_keys() {
        let doc = parse_toml("[pso]\nparticles = 8\n[run]\nseed = 1").unwrap();
        assert_eq!(doc[0].0, "pso.particles");
        assert_eq!(doc[1].0, "run.seed");
    }

    #[test]
    fn sections_listed_in_order_including_empty() {
        let text = "[a]\nk = 1\n[b.c]\n# comment only\n[d]\n";
        assert_eq!(toml_sections(text).unwrap(), vec!["a", "b.c", "d"]);
        assert!(toml_sections("[unclosed\n").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse_toml(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc[0].1, TomlValue::Str("a#b".into()));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_toml("ok = 1\nbroken").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(parse_toml("[unclosed\n").is_err());
        assert!(parse_toml("k = \"unterminated").is_err());
        assert!(parse_toml("k = what").is_err());
    }

    #[test]
    fn coercions() {
        assert_eq!(TomlValue::Int(3).as_float("k").unwrap(), 3.0);
        assert!(TomlValue::Str("x".into()).as_int("k").is_err());
        assert!(TomlValue::Bool(true).as_bool("k").unwrap());
    }
}
