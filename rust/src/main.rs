//! `cupso` — the launcher.
//!
//! Subcommands:
//!   run       solve one PSO workload with a chosen engine
//!   compare   run all five paper algorithms on one workload and rank them
//!   batch     run a multi-job TOML through the shared-pool scheduler
//!   simulate  print the Plane-C estimated-GPU tables (no execution)
//!   xla       drive the three-layer AOT stack (sync or async coordinator)
//!   info      platform, engines, fitness functions, artifact inventory
//!
//! `cupso <cmd> --help` lists options. A TOML config can seed any run:
//! `cupso run --config run.toml [overrides...]`; `cupso batch` reads a
//! multi-job file (see `config/batch_demo.toml`).

use anyhow::{bail, Context, Result};
use cupso::cli::{split_subcommand, Command};
use cupso::config::{BatchConfig, EngineKind, RunConfig};
use cupso::coordinator::{AsyncScheduler, CoordinatorConfig, SyncScheduler};
use cupso::engine::ParallelSettings;
use cupso::fitness::{by_name, Objective};
use cupso::gpusim;
use cupso::metrics::{Stopwatch, Table};
use cupso::pso::PsoParams;
use cupso::rng::RngKind;
use cupso::runtime::XlaRuntime;
use cupso::scheduler::{JobScheduler, JobSpec, SchedPolicy};
use std::path::Path;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let (cmd, rest) = split_subcommand(argv);
    match cmd {
        Some("run") => cmd_run(rest),
        Some("compare") => cmd_compare(rest),
        Some("batch") => cmd_batch(rest),
        Some("simulate") => cmd_simulate(rest),
        Some("xla") => cmd_xla(rest),
        Some("info") => cmd_info(rest),
        Some(other) => bail!("unknown command {other:?}\n\n{}", top_usage()),
        None => {
            println!("{}", top_usage());
            Ok(())
        }
    }
}

fn top_usage() -> String {
    "cupso — queue-based parallel PSO (cuPSO reproduction)\n\n\
     Commands:\n\
     \x20 run       solve one workload with a chosen engine\n\
     \x20 compare   rank all five paper algorithms on one workload\n\
     \x20 batch     run a multi-job TOML on one shared pool\n\
     \x20 simulate  print the estimated-GPU tables (Plane C)\n\
     \x20 xla       drive the AOT three-layer stack\n\
     \x20 info      platform + inventory\n\n\
     Try `cupso run --help`."
        .to_string()
}

/// Shared options → RunConfig.
fn run_command_spec(name: &'static str, about: &'static str) -> Command {
    Command::new(name, about)
        .opt("config", "TOML config file (flags override it)", None)
        .opt("fitness", "fitness function", Some("cubic"))
        .opt("particles", "swarm size", Some("1024"))
        .opt("dim", "dimensionality", Some("1"))
        .opt("iters", "iterations", Some("10000"))
        .opt("engine", "cpu|reduction|unroll|queue|queuelock", Some("queuelock"))
        .opt("workers", "worker threads (0 = all cores)", Some("0"))
        .opt("rng", "philox|xoshiro", Some("philox"))
        .opt("seed", "master seed", Some("42"))
        .opt("objective", "max|min (default: function's convention)", None)
        .switch("history", "print the convergence history")
}

fn parse_run_config(rest: &[String], spec: &Command) -> Result<(RunConfig, bool)> {
    let args = spec.parse(rest)?;
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.get("fitness") {
        cfg.fitness = v.to_string();
    }
    cfg.particles = args.get_parse("particles", cfg.particles)?;
    cfg.dim = args.get_parse("dim", cfg.dim)?;
    cfg.iters = args.get_parse("iters", cfg.iters)?;
    if let Some(v) = args.get("engine") {
        cfg.engine = EngineKind::parse(v).with_context(|| format!("bad engine {v}"))?;
    }
    cfg.workers = args.get_parse("workers", cfg.workers)?;
    if let Some(v) = args.get("rng") {
        cfg.rng = RngKind::parse(v).with_context(|| format!("bad rng {v}"))?;
    }
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    if let Some(v) = args.get("objective") {
        cfg.objective = Some(Objective::parse(v).with_context(|| format!("bad objective {v}"))?);
    }
    cfg.validate()?;
    Ok((cfg, args.flag("history")))
}

fn cmd_run(rest: &[String]) -> Result<()> {
    let spec = run_command_spec("run", "solve one PSO workload");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let (cfg, show_history) = parse_run_config(rest, &spec)?;
    let fitness = by_name(&cfg.fitness).unwrap();
    let objective = cfg.objective.unwrap_or(fitness.default_objective());
    let params = PsoParams::from_config(&cfg, fitness.as_ref());
    let mut engine = cupso::engine::build(cfg.engine, cfg.workers)
        .with_context(|| format!("engine {} needs the `xla` subcommand", cfg.engine))?;

    println!(
        "cupso run: {} × {}d × {} iters, engine={}, rng={}, seed={}",
        cfg.particles, cfg.dim, cfg.iters, cfg.engine, cfg.rng, cfg.seed
    );
    let sw = Stopwatch::start();
    let out = engine.run(&params, fitness.as_ref(), objective, cfg.seed);
    let elapsed = sw.elapsed_s();

    println!("gbest fitness  : {:.6}", out.gbest_fit);
    if let Some(opt) = fitness.optimum(cfg.dim) {
        println!("known optimum  : {opt:.6}");
    }
    let pos_preview: Vec<String> = out
        .gbest_pos
        .iter()
        .take(8)
        .map(|p| format!("{p:.4}"))
        .collect();
    println!(
        "gbest position : [{}{}]",
        pos_preview.join(", "),
        if cfg.dim > 8 { ", …" } else { "" }
    );
    println!("wall time      : {elapsed:.3}s");
    println!(
        "counters       : {} pbest improvements, {} queue pushes ({:.4}%), {} gbest updates",
        out.counters.pbest_improvements,
        out.counters.queue_pushes,
        100.0 * out.counters.queue_push_rate(),
        out.counters.gbest_updates
    );
    if show_history {
        let mut t = Table::new("Convergence", &["iteration", "gbest_fit"]);
        for (it, f) in &out.history {
            t.row(&[it.to_string(), format!("{f:.6}")]);
        }
        println!("{}", t.to_markdown());
    }
    Ok(())
}

fn cmd_compare(rest: &[String]) -> Result<()> {
    let spec = run_command_spec("compare", "rank all five paper algorithms");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let (cfg, _) = parse_run_config(rest, &spec)?;
    let fitness = by_name(&cfg.fitness).unwrap();
    let objective = cfg.objective.unwrap_or(fitness.default_objective());
    let params = PsoParams::from_config(&cfg, fitness.as_ref());

    let mut table = Table::new(
        &format!(
            "Engine comparison — {} n={} d={} iters={}",
            cfg.fitness, cfg.particles, cfg.dim, cfg.iters
        ),
        &["Engine", "Time (s)", "gbest", "vs best time"],
    );
    let mut rows = Vec::new();
    for kind in EngineKind::TABLE3 {
        let mut engine = cupso::engine::build(kind, cfg.workers).unwrap();
        let sw = Stopwatch::start();
        let out = engine.run(&params, fitness.as_ref(), objective, cfg.seed);
        rows.push((kind.label(), sw.elapsed_s(), out.gbest_fit));
    }
    let best = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    for (name, t, fit) in rows {
        table.row(&[
            name.to_string(),
            format!("{t:.3}"),
            format!("{fit:.3}"),
            format!("{:.2}x", t / best),
        ]);
    }
    println!("{}", table.to_markdown());
    Ok(())
}

fn cmd_batch(rest: &[String]) -> Result<()> {
    let spec = Command::new("batch", "run a multi-job TOML on one shared pool")
        .opt("config", "multi-job TOML file", Some("config/batch_demo.toml"))
        .opt("workers", "worker threads (0 = all cores; overrides the file)", None)
        .opt("policy", "round-robin|edf (overrides the file)", None)
        .opt("streams", "concurrent pool streams (overrides the file)", None)
        .opt("batch-steps", "iterations per job per round (overrides the file)", None)
        .switch("trace", "print every global-best improvement as it lands");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let mut cfg = BatchConfig::from_file(Path::new(args.get("config").unwrap()))?;
    if let Some(w) = args.get("workers") {
        cfg.workers = w
            .parse()
            .map_err(|e| anyhow::anyhow!("--workers {w:?}: {e}"))?;
    }
    if let Some(p) = args.get("policy") {
        cfg.policy = p.to_string();
    }
    if let Some(s) = args.get("streams") {
        cfg.streams = s
            .parse()
            .map_err(|e| anyhow::anyhow!("--streams {s:?}: {e}"))?;
    }
    if let Some(b) = args.get("batch-steps") {
        cfg.batch_steps = b
            .parse()
            .map_err(|e| anyhow::anyhow!("--batch-steps {b:?}: {e}"))?;
    }
    if cfg.streams == 0 || cfg.batch_steps == 0 {
        bail!("--streams and --batch-steps must be >= 1");
    }
    let policy = SchedPolicy::parse(&cfg.policy)
        .with_context(|| format!("bad policy {:?} (round-robin|edf)", cfg.policy))?;
    let trace = args.flag("trace");

    let specs: Vec<JobSpec> = cfg
        .jobs
        .iter()
        .map(JobSpec::from_config)
        .collect::<Result<_>>()?;
    let scheduler = JobScheduler::new(ParallelSettings::with_streams(cfg.workers, cfg.streams))
        .policy(policy)
        .batch_steps(cfg.batch_steps);
    println!(
        "cupso batch: {} jobs, {} policy, {} pool workers, {} streams, {} steps/round",
        specs.len(),
        policy,
        scheduler.pool().workers(),
        scheduler.streams(),
        cfg.batch_steps
    );

    // One JobReport per stepped job per scheduling round (so with
    // --streams > 1 several reports share a round).
    let mut reports = 0u64;
    let mut improvements = 0u64;
    let sw = Stopwatch::start();
    let outcomes = scheduler.run_with(&specs, |r| {
        reports += 1;
        if r.improved {
            improvements += 1;
            if trace {
                println!("  [{}] iter {:>6}  gbest {:.6}", r.name, r.iter, r.gbest_fit);
            }
        }
    })?;
    let elapsed = sw.elapsed_s();
    // A telemetry report covers a whole round (batch_steps iterations),
    // so iteration throughput comes from the outcomes, not the report
    // count.
    let total_steps: u64 = outcomes.iter().map(|o| o.steps).sum();

    let mut table = Table::new(
        "Batch results",
        &["Job", "Engine", "Workload", "Steps", "Stop", "gbest"],
    );
    for (o, s) in outcomes.iter().zip(&specs) {
        table.row(&[
            o.name.clone(),
            o.engine.label().to_string(),
            format!("{}x{}d", s.params.n, s.params.dim),
            o.steps.to_string(),
            o.stop.to_string(),
            format!("{:.6}", o.output.gbest_fit),
        ]);
    }
    println!("{}", table.to_markdown());
    println!(
        "aggregate: {} jobs in {:.3}s — {:.1} jobs/s, {} steps ({:.0} steps/s), \
         {} job-reports ({} improving)",
        outcomes.len(),
        elapsed,
        outcomes.len() as f64 / elapsed.max(1e-9),
        total_steps,
        total_steps as f64 / elapsed.max(1e-9),
        reports,
        improvements
    );
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> Result<()> {
    let spec = Command::new("simulate", "print the Plane-C estimated-GPU tables")
        .opt("table", "3|4|5|all", Some("all"));
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let which = args.get("table").unwrap_or("all");
    if which == "3" || which == "all" {
        let mut t = Table::new(
            "Table 3 (estimated GTX-1080Ti vs paper) — 1-D, 100k iters",
            &["Particles", "CPU", "Reduction", "Unroll", "Queue", "QueueLock", "paper QueueLock"],
        );
        for (n, _, _, _, _, p_ql) in gpusim::paper::TABLE3 {
            let est = |k| gpusim::estimate_seconds(k, n, 1, 100_000);
            t.row(&[
                n.to_string(),
                format!("{:.3}", est(EngineKind::SerialCpu)),
                format!("{:.3}", est(EngineKind::Reduction)),
                format!("{:.3}", est(EngineKind::LoopUnrolling)),
                format!("{:.3}", est(EngineKind::Queue)),
                format!("{:.3}", est(EngineKind::QueueLock)),
                format!("{p_ql:.3}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if which == "4" || which == "all" {
        let mut t = Table::new(
            "Table 4 (estimated) — 1-D speedup, CPU vs Queue Lock",
            &["Particles", "CPU (s)", "QueueLock (s)", "Speedup", "paper"],
        );
        for (n, _, _, p_s) in gpusim::paper::TABLE4 {
            let c = gpusim::estimate_seconds(EngineKind::SerialCpu, n, 1, 100_000);
            let g = gpusim::estimate_seconds(EngineKind::QueueLock, n, 1, 100_000);
            t.row(&[
                n.to_string(),
                format!("{c:.3}"),
                format!("{g:.3}"),
                format!("{:.2}", c / g),
                format!("{p_s:.2}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if which == "5" || which == "all" {
        let mut t = Table::new(
            "Table 5 (estimated) — 120-D speedup, CPU vs Queue",
            &["Particles", "Iters", "CPU (s)", "Queue (s)", "Speedup", "paper"],
        );
        for ((n, iters), (_, _, _, _, p_s)) in
            gpusim::TABLE5_ROWS.iter().zip(gpusim::paper::TABLE5.iter())
        {
            let c = gpusim::estimate_seconds(EngineKind::SerialCpu, *n, 120, *iters);
            let g = gpusim::estimate_seconds(EngineKind::Queue, *n, 120, *iters);
            t.row(&[
                n.to_string(),
                iters.to_string(),
                format!("{c:.3}"),
                format!("{g:.3}"),
                format!("{:.2}", c / g),
                format!("{p_s:.2}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    Ok(())
}

fn cmd_xla(rest: &[String]) -> Result<()> {
    let spec = Command::new("xla", "drive the three-layer AOT stack")
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("variant", "reduction|queue|fused", Some("queue"))
        .opt("particles", "particles per shard (must match an artifact)", Some("1024"))
        .opt("dim", "dimensionality (must match an artifact)", Some("1"))
        .opt("shards", "independent shards", Some("4"))
        .opt("iters", "iterations per shard", Some("500"))
        .opt("seed", "master seed", Some("42"))
        .opt("scheduler", "sync|async", Some("async"));
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let rt = XlaRuntime::open(Path::new(args.get("artifacts").unwrap()))?;
    let mut cfg = CoordinatorConfig::new(
        args.get("variant").unwrap(),
        args.get_parse("particles", 1024usize)?,
        args.get_parse("dim", 1usize)?,
        args.get_parse("iters", 500u64)?,
    );
    cfg.shards = args.get_parse("shards", 4usize)?;
    cfg.seed = args.get_parse("seed", 42u64)?;
    let scheduler = args.get("scheduler").unwrap_or("async");

    println!(
        "cupso xla: platform={}, variant={}, {} shards × {} particles × {}d, {} iters, {} scheduler",
        rt.platform(),
        cfg.variant,
        cfg.shards,
        cfg.shard_particles,
        cfg.dim,
        cfg.iters,
        scheduler
    );
    let sw = Stopwatch::start();
    let out = match scheduler {
        "sync" => SyncScheduler::run(&rt, &cfg)?,
        "async" => AsyncScheduler::run(&rt, &cfg)?,
        other => bail!("unknown scheduler {other} (sync|async)"),
    };
    let elapsed = sw.elapsed_s();
    println!("gbest fitness : {:.6}", out.gbest_fit);
    println!("wall time     : {elapsed:.3}s");
    println!(
        "chunk calls   : {} ({} iters/shard), merges: {}",
        out.chunk_calls, out.iters_per_shard, out.merges
    );
    println!(
        "shard fits    : {:?}",
        out.shard_fits.iter().map(|f| format!("{f:.1}")).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_info(rest: &[String]) -> Result<()> {
    let spec = Command::new("info", "platform + inventory")
        .opt("artifacts", "artifact directory", Some("artifacts"));
    let args = spec.parse(rest)?;
    println!("cupso {} — cuPSO (SAC'22) reproduction", env!("CARGO_PKG_VERSION"));
    println!(
        "cores: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );
    println!("engines: cpu, reduction, unroll, queue, queuelock (+ xla sync/async)");
    println!("fitness: {}", cupso::fitness::ALL_NAMES.join(", "));
    let dir = Path::new(args.get("artifacts").unwrap());
    match XlaRuntime::open(dir) {
        Ok(rt) => {
            println!("artifacts ({}, jax {}):", rt.platform(), rt.manifest().jax_version);
            for m in rt.manifest().iter() {
                println!(
                    "  {:<28} variant={:<9} n={:<6} d={:<3} k={}",
                    m.name, m.variant, m.n, m.dim, m.iters
                );
            }
        }
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    Ok(())
}
