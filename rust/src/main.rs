//! `cupso` — the launcher.
//!
//! Subcommands:
//!   run       solve one PSO workload with a chosen engine
//!   compare   run all five paper algorithms on one workload and rank them
//!   batch     run a multi-job TOML through the shared-pool scheduler
//!             (optionally checkpointing every job into --checkpoint-dir)
//!   resume    continue a suspended/checkpointed batch from its directory
//!   simulate  print the Plane-C estimated-GPU tables (no execution)
//!   xla       drive the three-layer AOT stack (sync or async coordinator)
//!   info      platform, engines, fitness functions, artifact inventory
//!
//! `cupso <cmd> --help` lists options. A TOML config can seed any run:
//! `cupso run --config run.toml [overrides...]`; `cupso batch` reads a
//! multi-job file (see `config/batch_demo.toml`).

use anyhow::{bail, Context, Result};
use cupso::checkpoint::JobCheckpoint;
use cupso::cli::{split_subcommand, Command};
use cupso::config::{parse_toml, BatchConfig, EngineKind, RunConfig, TomlValue};
use cupso::coordinator::{AsyncScheduler, CoordinatorConfig, SyncScheduler};
use cupso::engine::ParallelSettings;
use cupso::fitness::{by_name, Objective};
use cupso::gpusim;
use cupso::metrics::{Stopwatch, Table};
use cupso::pso::PsoParams;
use cupso::rng::RngKind;
use cupso::runtime::XlaRuntime;
use cupso::scheduler::{
    BatchRun, JobOutcome, JobReport, JobScheduler, JobSpec, SchedPolicy, TerminationCriteria,
};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let (cmd, rest) = split_subcommand(argv);
    match cmd {
        Some("run") => cmd_run(rest),
        Some("compare") => cmd_compare(rest),
        Some("batch") => cmd_batch(rest),
        Some("resume") => cmd_resume(rest),
        Some("simulate") => cmd_simulate(rest),
        Some("xla") => cmd_xla(rest),
        Some("info") => cmd_info(rest),
        Some(other) => bail!("unknown command {other:?}\n\n{}", top_usage()),
        None => {
            println!("{}", top_usage());
            Ok(())
        }
    }
}

fn top_usage() -> String {
    "cupso — queue-based parallel PSO (cuPSO reproduction)\n\n\
     Commands:\n\
     \x20 run       solve one workload with a chosen engine\n\
     \x20 compare   rank all five paper algorithms on one workload\n\
     \x20 batch     run a multi-job TOML on one shared pool\n\
     \x20 resume    continue a checkpointed batch from its directory\n\
     \x20 simulate  print the estimated-GPU tables (Plane C)\n\
     \x20 xla       drive the AOT three-layer stack\n\
     \x20 info      platform + inventory\n\n\
     Try `cupso run --help`."
        .to_string()
}

/// Shared options → RunConfig.
fn run_command_spec(name: &'static str, about: &'static str) -> Command {
    Command::new(name, about)
        .opt("config", "TOML config file (flags override it)", None)
        .opt("fitness", "fitness function", Some("cubic"))
        .opt("particles", "swarm size", Some("1024"))
        .opt("dim", "dimensionality", Some("1"))
        .opt("iters", "iterations", Some("10000"))
        .opt("engine", "cpu|reduction|unroll|queue|queuelock", Some("queuelock"))
        .opt("workers", "worker threads (0 = all cores)", Some("0"))
        .opt("rng", "philox|xoshiro", Some("philox"))
        .opt("seed", "master seed", Some("42"))
        .opt("objective", "max|min (default: function's convention)", None)
        .switch("history", "print the convergence history")
}

fn parse_run_config(rest: &[String], spec: &Command) -> Result<(RunConfig, bool)> {
    let args = spec.parse(rest)?;
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.get("fitness") {
        cfg.fitness = v.to_string();
    }
    cfg.particles = args.get_parse("particles", cfg.particles)?;
    cfg.dim = args.get_parse("dim", cfg.dim)?;
    cfg.iters = args.get_parse("iters", cfg.iters)?;
    if let Some(v) = args.get("engine") {
        cfg.engine = EngineKind::parse(v).with_context(|| format!("bad engine {v}"))?;
    }
    cfg.workers = args.get_parse("workers", cfg.workers)?;
    if let Some(v) = args.get("rng") {
        cfg.rng = RngKind::parse(v).with_context(|| format!("bad rng {v}"))?;
    }
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    if let Some(v) = args.get("objective") {
        cfg.objective = Some(Objective::parse(v).with_context(|| format!("bad objective {v}"))?);
    }
    cfg.validate()?;
    Ok((cfg, args.flag("history")))
}

fn cmd_run(rest: &[String]) -> Result<()> {
    let spec = run_command_spec("run", "solve one PSO workload");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let (cfg, show_history) = parse_run_config(rest, &spec)?;
    let fitness = by_name(&cfg.fitness).unwrap();
    let objective = cfg.objective.unwrap_or(fitness.default_objective());
    let params = PsoParams::from_config(&cfg, fitness.as_ref());
    let mut engine = cupso::engine::build(cfg.engine, cfg.workers)
        .with_context(|| format!("engine {} needs the `xla` subcommand", cfg.engine))?;

    println!(
        "cupso run: {} × {}d × {} iters, engine={}, rng={}, seed={}",
        cfg.particles, cfg.dim, cfg.iters, cfg.engine, cfg.rng, cfg.seed
    );
    let sw = Stopwatch::start();
    let out = engine.run(&params, fitness.as_ref(), objective, cfg.seed);
    let elapsed = sw.elapsed_s();

    println!("gbest fitness  : {:.6}", out.gbest_fit);
    if let Some(opt) = fitness.optimum(cfg.dim) {
        println!("known optimum  : {opt:.6}");
    }
    let pos_preview: Vec<String> = out
        .gbest_pos
        .iter()
        .take(8)
        .map(|p| format!("{p:.4}"))
        .collect();
    println!(
        "gbest position : [{}{}]",
        pos_preview.join(", "),
        if cfg.dim > 8 { ", …" } else { "" }
    );
    println!("wall time      : {elapsed:.3}s");
    println!(
        "counters       : {} pbest improvements, {} queue pushes ({:.4}%), {} gbest updates",
        out.counters.pbest_improvements,
        out.counters.queue_pushes,
        100.0 * out.counters.queue_push_rate(),
        out.counters.gbest_updates
    );
    if show_history {
        let mut t = Table::new("Convergence", &["iteration", "gbest_fit"]);
        for (it, f) in &out.history {
            t.row(&[it.to_string(), format!("{f:.6}")]);
        }
        println!("{}", t.to_markdown());
    }
    Ok(())
}

fn cmd_compare(rest: &[String]) -> Result<()> {
    let spec = run_command_spec("compare", "rank all five paper algorithms");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let (cfg, _) = parse_run_config(rest, &spec)?;
    let fitness = by_name(&cfg.fitness).unwrap();
    let objective = cfg.objective.unwrap_or(fitness.default_objective());
    let params = PsoParams::from_config(&cfg, fitness.as_ref());

    let mut table = Table::new(
        &format!(
            "Engine comparison — {} n={} d={} iters={}",
            cfg.fitness, cfg.particles, cfg.dim, cfg.iters
        ),
        &["Engine", "Time (s)", "gbest", "vs best time"],
    );
    let mut rows = Vec::new();
    for kind in EngineKind::TABLE3 {
        let mut engine = cupso::engine::build(kind, cfg.workers).unwrap();
        let sw = Stopwatch::start();
        let out = engine.run(&params, fitness.as_ref(), objective, cfg.seed);
        rows.push((kind.label(), sw.elapsed_s(), out.gbest_fit));
    }
    let best = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    for (name, t, fit) in rows {
        table.row(&[
            name.to_string(),
            format!("{t:.3}"),
            format!("{fit:.3}"),
            format!("{:.2}x", t / best),
        ]);
    }
    println!("{}", table.to_markdown());
    Ok(())
}

fn cmd_batch(rest: &[String]) -> Result<()> {
    let spec = Command::new("batch", "run a multi-job TOML on one shared pool")
        .opt("config", "multi-job TOML file", Some("config/batch_demo.toml"))
        .opt("workers", "worker threads (0 = all cores; overrides the file)", None)
        .opt("policy", "round-robin|edf (overrides the file)", None)
        .opt("streams", "concurrent pool streams (overrides the file)", None)
        .opt("batch-steps", "iterations per job per round (overrides the file)", None)
        .opt(
            "preempt-quantum",
            "suspend a job to a checkpoint after this many steps when jobs \
             outnumber streams; 0 = cooperative (overrides the file)",
            None,
        )
        .opt(
            "checkpoint-dir",
            "write periodic per-job checkpoints here (enables `cupso resume`)",
            None,
        )
        .opt(
            "checkpoint-every",
            "scheduling rounds between periodic checkpoints",
            Some("64"),
        )
        .opt(
            "checkpoint-keep",
            "retained snapshots: 1 = overwrite in place, N > 1 = rotate \
             snap_<seq>/ directories keeping the latest N",
            Some("1"),
        )
        .opt(
            "suspend-after",
            "suspend the whole batch to --checkpoint-dir after this many rounds and exit",
            None,
        )
        .switch("trace", "print every global-best improvement as it lands");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let mut cfg = BatchConfig::from_file(Path::new(args.get("config").unwrap()))?;
    if let Some(w) = args.get("workers") {
        cfg.workers = w
            .parse()
            .map_err(|e| anyhow::anyhow!("--workers {w:?}: {e}"))?;
    }
    if let Some(p) = args.get("policy") {
        cfg.policy = p.to_string();
    }
    if let Some(s) = args.get("streams") {
        cfg.streams = s
            .parse()
            .map_err(|e| anyhow::anyhow!("--streams {s:?}: {e}"))?;
    }
    if let Some(b) = args.get("batch-steps") {
        cfg.batch_steps = b
            .parse()
            .map_err(|e| anyhow::anyhow!("--batch-steps {b:?}: {e}"))?;
    }
    if let Some(q) = args.get("preempt-quantum") {
        cfg.preempt_quantum = q
            .parse()
            .map_err(|e| anyhow::anyhow!("--preempt-quantum {q:?}: {e}"))?;
    }
    if cfg.streams == 0 || cfg.batch_steps == 0 {
        bail!("--streams and --batch-steps must be >= 1");
    }
    let policy = SchedPolicy::parse(&cfg.policy)
        .with_context(|| format!("bad policy {:?} (round-robin|edf)", cfg.policy))?;
    let trace = args.flag("trace");
    let ckpt_dir = args.get("checkpoint-dir").map(PathBuf::from);
    let every: u64 = args.get_parse("checkpoint-every", 64u64)?;
    let keep: usize = args.get_parse("checkpoint-keep", 1usize)?;
    let suspend_after: Option<u64> = args
        .get("suspend-after")
        .map(|s| {
            s.parse()
                .map_err(|e| anyhow::anyhow!("--suspend-after {s:?}: {e}"))
        })
        .transpose()?;
    if every == 0 {
        bail!("--checkpoint-every must be >= 1");
    }
    if keep == 0 {
        bail!("--checkpoint-keep must be >= 1");
    }
    if suspend_after.is_some() && ckpt_dir.is_none() {
        bail!("--suspend-after requires --checkpoint-dir");
    }

    let specs: Vec<JobSpec> = cfg
        .jobs
        .iter()
        .map(JobSpec::from_config)
        .collect::<Result<_>>()?;
    let scheduler = JobScheduler::new(ParallelSettings::with_streams(cfg.workers, cfg.streams))
        .policy(policy)
        .batch_steps(cfg.batch_steps)
        .preempt_quantum(cfg.preempt_quantum);
    println!(
        "cupso batch: {} jobs, {} policy, {} pool workers, {} streams, {} steps/round{}",
        specs.len(),
        policy,
        scheduler.pool().workers(),
        scheduler.streams(),
        cfg.batch_steps,
        if cfg.preempt_quantum > 0 {
            format!(", preemption quantum {}", cfg.preempt_quantum)
        } else {
            String::new()
        }
    );

    // One JobReport per stepped job per scheduling round (so with
    // --streams > 1 several reports share a round).
    let mut reports = 0u64;
    let mut improvements = 0u64;
    let sw = Stopwatch::start();
    let mut telemetry = |r: &JobReport<'_>| {
        reports += 1;
        if r.improved {
            improvements += 1;
            if trace {
                println!("  [{}] iter {:>6}  gbest {:.6}", r.name, r.iter, r.gbest_fit);
            }
        }
    };
    let outcomes = match &ckpt_dir {
        None => scheduler.run_with(&specs, &mut telemetry)?,
        Some(dir) => {
            let completed = drive_session(
                &scheduler,
                &specs,
                &cfg,
                dir,
                every,
                keep,
                suspend_after,
                None,
                &mut telemetry,
            )?;
            match completed {
                Some(outcomes) => outcomes,
                None => return Ok(()), // suspended on request; message printed
            }
        }
    };
    let elapsed = sw.elapsed_s();
    print_batch_results(&outcomes, &specs, elapsed, reports, improvements);
    Ok(())
}

/// Continue a checkpointed batch: `cupso resume <dir>` reconstructs the
/// jobs and scheduler from the directory `cupso batch --checkpoint-dir`
/// wrote, restores every job and runs the batch to termination —
/// bit-identically to the never-interrupted batch for the deterministic
/// engines.
fn cmd_resume(rest: &[String]) -> Result<()> {
    let spec = Command::new("resume", "continue a checkpointed batch from its directory")
        .opt(
            "checkpoint-every",
            "scheduling rounds between refreshed checkpoints",
            Some("64"),
        )
        .switch("trace", "print every global-best improvement as it lands");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        println!("usage: cupso resume <checkpoint-dir>");
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let dir = args
        .positional
        .first()
        .map(PathBuf::from)
        .context("usage: cupso resume <checkpoint-dir>")?;
    let every: u64 = args.get_parse("checkpoint-every", 64u64)?;
    if every == 0 {
        bail!("--checkpoint-every must be >= 1");
    }
    let trace = args.flag("trace");

    let snap_dir = resolve_snapshot_dir(&dir)?;
    let (knobs, keep, ckpts) = read_snapshot(&snap_dir)?;
    let specs = specs_from_checkpoints(&ckpts)?;
    let policy = SchedPolicy::parse(&knobs.policy)
        .with_context(|| format!("manifest: bad policy {:?}", knobs.policy))?;
    let scheduler = JobScheduler::new(ParallelSettings::with_streams(knobs.workers, knobs.streams))
        .policy(policy)
        .batch_steps(knobs.batch_steps)
        .preempt_quantum(knobs.preempt_quantum);
    let done = ckpts.iter().filter(|c| c.stop.is_some()).count();
    println!(
        "cupso resume: {} jobs from {} ({} already finished), {} policy, {} streams",
        specs.len(),
        dir.display(),
        done,
        policy,
        scheduler.streams()
    );

    let mut reports = 0u64;
    let mut improvements = 0u64;
    let sw = Stopwatch::start();
    let mut telemetry = |r: &JobReport<'_>| {
        reports += 1;
        if r.improved {
            improvements += 1;
            if trace {
                println!("  [{}] iter {:>6}  gbest {:.6}", r.name, r.iter, r.gbest_fit);
            }
        }
    };
    let outcomes = drive_session(
        &scheduler,
        &specs,
        &knobs,
        &dir,
        every,
        keep,
        None,
        Some(ckpts),
        &mut telemetry,
    )?
    .expect("resume without --suspend-after runs to completion");
    let elapsed = sw.elapsed_s();
    print_batch_results(&outcomes, &specs, elapsed, reports, improvements);
    Ok(())
}

/// Single-session driver shared by `batch --checkpoint-dir` and `resume`:
/// run ONE scheduler session with the in-place persistence hook — every
/// `every` rounds a full snapshot is written while the batch keeps
/// running (no suspend/restore churn, no buffer reallocation). `Ok(None)`
/// means the batch was deliberately suspended (`suspend_after`, final
/// snapshot written); `Ok(Some(outcomes))` means it completed.
#[allow(clippy::too_many_arguments)]
fn drive_session<F: FnMut(&JobReport<'_>)>(
    scheduler: &JobScheduler,
    specs: &[JobSpec],
    cfg: &BatchConfig,
    dir: &Path,
    every: u64,
    keep: usize,
    suspend_after: Option<u64>,
    resume: Option<Vec<JobCheckpoint>>,
    telemetry: F,
) -> Result<Option<Vec<JobOutcome>>> {
    let mut sink = SnapshotSink::new(dir, cfg, keep)?;
    let batch = scheduler.run_session_with(
        specs,
        resume.as_deref(),
        suspend_after,
        Some(every),
        |snap| sink.persist(snap),
        telemetry,
    )?;
    match batch {
        BatchRun::Complete(outcomes) => Ok(Some(outcomes)),
        BatchRun::Suspended(snap) => {
            sink.persist(&snap)?;
            println!(
                "suspended {} jobs into {} — continue with `cupso resume {}`",
                snap.len(),
                dir.display(),
                dir.display()
            );
            Ok(None)
        }
    }
}

/// Writes batch snapshots under a checkpoint directory, with retention.
///
/// `keep == 1` (the default) overwrites the directory in place — the
/// layout `cupso resume` has always read. `keep > 1` rotates numbered
/// `snap_<seq>/` subdirectories, pruning so the latest `keep` survive
/// (ROADMAP retention item); `resolve_snapshot_dir` picks the newest on
/// resume. One encode buffer is reused across every checkpoint written.
struct SnapshotSink<'a> {
    dir: &'a Path,
    cfg: &'a BatchConfig,
    keep: usize,
    seq: u64,
    buf: Vec<u8>,
}

impl<'a> SnapshotSink<'a> {
    fn new(dir: &'a Path, cfg: &'a BatchConfig, keep: usize) -> Result<Self> {
        // Continue numbering after any snapshots a previous run left.
        let seq = match list_rotated(dir) {
            Ok(existing) => existing.last().map_or(0, |&(s, _)| s + 1),
            Err(_) => 0, // directory does not exist yet
        };
        Ok(Self {
            dir,
            cfg,
            keep,
            seq,
            buf: Vec::new(),
        })
    }

    fn persist(&mut self, snap: &[JobCheckpoint]) -> Result<()> {
        if self.keep <= 1 {
            return write_snapshot(self.dir, self.cfg, self.keep, snap, &mut self.buf);
        }
        let target = self.dir.join(format!("snap_{:06}", self.seq));
        write_snapshot(&target, self.cfg, self.keep, snap, &mut self.buf)?;
        self.seq += 1;
        // Prune: keep the latest `keep` rotated snapshots.
        let existing = list_rotated(self.dir)?;
        for (_, path) in existing.iter().rev().skip(self.keep) {
            std::fs::remove_dir_all(path)
                .with_context(|| format!("pruning old snapshot {}", path.display()))?;
        }
        Ok(())
    }
}

/// Numbered `snap_<seq>/` subdirectories holding a manifest, ascending.
fn list_rotated(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut found = Vec::new();
    for entry in std::fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(seq) = name.strip_prefix("snap_").and_then(|s| s.parse::<u64>().ok()) {
            if path.join("manifest.toml").exists() {
                found.push((seq, path));
            }
        }
    }
    found.sort_unstable_by_key(|&(s, _)| s);
    Ok(found)
}

/// The snapshot directory `cupso resume` should read: the directory
/// itself when it holds a manifest (keep = 1 layout), otherwise the
/// newest rotated `snap_<seq>/` subdirectory.
fn resolve_snapshot_dir(dir: &Path) -> Result<PathBuf> {
    if dir.join("manifest.toml").exists() {
        return Ok(dir.to_path_buf());
    }
    let mut rotated = list_rotated(dir).unwrap_or_default();
    rotated.pop().map(|(_, p)| p).with_context(|| {
        format!(
            "no manifest.toml or snap_*/ snapshot under {}",
            dir.display()
        )
    })
}

/// Persist a batch snapshot: one `job_<i>.ckpt` per job plus a
/// `manifest.toml` recording the scheduler knobs and job count. `buf` is
/// the reusable encode buffer.
fn write_snapshot(
    dir: &Path,
    cfg: &BatchConfig,
    keep: usize,
    snap: &[JobCheckpoint],
    buf: &mut Vec<u8>,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
    for (i, job) in snap.iter().enumerate() {
        job.write_file_with(&dir.join(format!("job_{i}.ckpt")), buf)?;
    }
    let manifest = format!(
        "# cupso batch snapshot — continue with `cupso resume {}`\n\
         version = {}\n\
         workers = {}\n\
         policy = \"{}\"\n\
         streams = {}\n\
         batch_steps = {}\n\
         preempt_quantum = {}\n\
         keep = {}\n\
         jobs = {}\n",
        dir.display(),
        cupso::checkpoint::VERSION,
        cfg.workers,
        cfg.policy,
        cfg.streams,
        cfg.batch_steps,
        cfg.preempt_quantum,
        keep,
        snap.len()
    );
    // Atomic like the job checkpoints: a crash mid-write must never tear
    // the manifest, or the whole snapshot becomes unresumable.
    let tmp = dir.join("manifest.toml.tmp");
    std::fs::write(&tmp, manifest)
        .with_context(|| format!("writing manifest in {}", dir.display()))?;
    std::fs::rename(&tmp, dir.join("manifest.toml"))
        .with_context(|| format!("publishing manifest in {}", dir.display()))?;
    Ok(())
}

/// Load a batch snapshot directory: scheduler knobs (as a job-less
/// `BatchConfig`) plus the retention count and every job checkpoint in
/// manifest order.
fn read_snapshot(dir: &Path) -> Result<(BatchConfig, usize, Vec<JobCheckpoint>)> {
    let manifest_path = dir.join("manifest.toml");
    let text = std::fs::read_to_string(&manifest_path)
        .with_context(|| format!("reading {}", manifest_path.display()))?;
    let doc: BTreeMap<String, TomlValue> = parse_toml(&text)?.into_iter().collect();
    // Loud on anything out of range — a hand-edited or torn manifest must
    // never wrap into a huge thread count or silently clamp a knob. The
    // caps are per-key: resource-shaped knobs (workers/streams/jobs) get
    // tight plausibility bounds, step-denominated knobs only reject
    // negatives (batch wrote whatever the user asked for).
    let get_uint = |key: &str, max: u64| -> Result<u64> {
        let v = doc
            .get(key)
            .with_context(|| format!("manifest: missing key {key:?}"))?
            .as_int(key)?;
        if v < 0 || v as u64 > max {
            bail!("manifest: {key} = {v} out of range");
        }
        Ok(v as u64)
    };
    let version = get_uint("version", u32::MAX as u64)?;
    if version != cupso::checkpoint::VERSION as u64 {
        bail!(
            "manifest: snapshot version {version} unsupported (this build reads {})",
            cupso::checkpoint::VERSION
        );
    }
    let streams = get_uint("streams", 1_000_000)?;
    let batch_steps = get_uint("batch_steps", u64::MAX)?;
    if streams == 0 || batch_steps == 0 {
        bail!("manifest: streams and batch_steps must be >= 1");
    }
    let knobs = BatchConfig {
        workers: get_uint("workers", 1_000_000)? as usize,
        policy: doc
            .get("policy")
            .context("manifest: missing key \"policy\"")?
            .as_str("policy")?
            .to_string(),
        streams: streams as usize,
        batch_steps,
        preempt_quantum: get_uint("preempt_quantum", u64::MAX)?,
        jobs: Vec::new(),
    };
    // Optional for compatibility with pre-rotation snapshots.
    let keep = match doc.get("keep") {
        Some(v) => {
            let k = v.as_int("keep")?;
            if !(1..=1_000_000).contains(&k) {
                bail!("manifest: keep = {k} out of range");
            }
            k as usize
        }
        None => 1,
    };
    let job_count = get_uint("jobs", 100_000)?;
    let mut ckpts = Vec::with_capacity(job_count as usize);
    for i in 0..job_count {
        ckpts.push(JobCheckpoint::read_file(&dir.join(format!("job_{i}.ckpt")))?);
    }
    Ok((knobs, keep, ckpts))
}

/// Rebuild scheduler job specs from suspended checkpoints: workload,
/// engine, seed and objective come from the run state; fitness and the
/// termination bounds from the job wrapper.
fn specs_from_checkpoints(ckpts: &[JobCheckpoint]) -> Result<Vec<JobSpec>> {
    ckpts
        .iter()
        .map(|c| {
            let fitness = by_name(&c.fitness)
                .with_context(|| format!("job {}: unknown fitness {:?}", c.name, c.fitness))?;
            let engine = c.run.kind.engine_kind().with_context(|| {
                format!("job {}: run kind {} is not schedulable", c.name, c.run.kind)
            })?;
            let mut spec = JobSpec::new(
                &c.name,
                engine,
                c.run.params.clone(),
                Arc::from(fitness),
                c.run.objective,
                c.run.seed,
            );
            spec.termination = TerminationCriteria {
                max_iter: c.max_steps,
                target_fit: c.target_fit,
                stall_window: c.stall_window,
            };
            spec.deadline = c.deadline;
            Ok(spec)
        })
        .collect()
}

fn print_batch_results(
    outcomes: &[JobOutcome],
    specs: &[JobSpec],
    elapsed: f64,
    reports: u64,
    improvements: u64,
) {
    // A telemetry report covers a whole round (batch_steps iterations),
    // so iteration throughput comes from the outcomes, not the report
    // count.
    let total_steps: u64 = outcomes.iter().map(|o| o.steps).sum();
    let mut table = Table::new(
        "Batch results",
        &["Job", "Engine", "Workload", "Steps", "Stop", "gbest"],
    );
    for (o, s) in outcomes.iter().zip(specs) {
        table.row(&[
            o.name.to_string(),
            o.engine.label().to_string(),
            format!("{}x{}d", s.params.n, s.params.dim),
            o.steps.to_string(),
            o.stop.to_string(),
            format!("{:.6}", o.output.gbest_fit),
        ]);
    }
    println!("{}", table.to_markdown());
    println!(
        "aggregate: {} jobs in {:.3}s — {:.1} jobs/s, {} steps ({:.0} steps/s), \
         {} job-reports ({} improving)",
        outcomes.len(),
        elapsed,
        outcomes.len() as f64 / elapsed.max(1e-9),
        total_steps,
        total_steps as f64 / elapsed.max(1e-9),
        reports,
        improvements
    );
}

fn cmd_simulate(rest: &[String]) -> Result<()> {
    let spec = Command::new("simulate", "print the Plane-C estimated-GPU tables")
        .opt("table", "3|4|5|all", Some("all"));
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let which = args.get("table").unwrap_or("all");
    if which == "3" || which == "all" {
        let mut t = Table::new(
            "Table 3 (estimated GTX-1080Ti vs paper) — 1-D, 100k iters",
            &["Particles", "CPU", "Reduction", "Unroll", "Queue", "QueueLock", "paper QueueLock"],
        );
        for (n, _, _, _, _, p_ql) in gpusim::paper::TABLE3 {
            let est = |k| gpusim::estimate_seconds(k, n, 1, 100_000);
            t.row(&[
                n.to_string(),
                format!("{:.3}", est(EngineKind::SerialCpu)),
                format!("{:.3}", est(EngineKind::Reduction)),
                format!("{:.3}", est(EngineKind::LoopUnrolling)),
                format!("{:.3}", est(EngineKind::Queue)),
                format!("{:.3}", est(EngineKind::QueueLock)),
                format!("{p_ql:.3}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if which == "4" || which == "all" {
        let mut t = Table::new(
            "Table 4 (estimated) — 1-D speedup, CPU vs Queue Lock",
            &["Particles", "CPU (s)", "QueueLock (s)", "Speedup", "paper"],
        );
        for (n, _, _, p_s) in gpusim::paper::TABLE4 {
            let c = gpusim::estimate_seconds(EngineKind::SerialCpu, n, 1, 100_000);
            let g = gpusim::estimate_seconds(EngineKind::QueueLock, n, 1, 100_000);
            t.row(&[
                n.to_string(),
                format!("{c:.3}"),
                format!("{g:.3}"),
                format!("{:.2}", c / g),
                format!("{p_s:.2}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if which == "5" || which == "all" {
        let mut t = Table::new(
            "Table 5 (estimated) — 120-D speedup, CPU vs Queue",
            &["Particles", "Iters", "CPU (s)", "Queue (s)", "Speedup", "paper"],
        );
        for ((n, iters), (_, _, _, _, p_s)) in
            gpusim::TABLE5_ROWS.iter().zip(gpusim::paper::TABLE5.iter())
        {
            let c = gpusim::estimate_seconds(EngineKind::SerialCpu, *n, 120, *iters);
            let g = gpusim::estimate_seconds(EngineKind::Queue, *n, 120, *iters);
            t.row(&[
                n.to_string(),
                iters.to_string(),
                format!("{c:.3}"),
                format!("{g:.3}"),
                format!("{:.2}", c / g),
                format!("{p_s:.2}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    Ok(())
}

fn cmd_xla(rest: &[String]) -> Result<()> {
    let spec = Command::new("xla", "drive the three-layer AOT stack")
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("variant", "reduction|queue|fused", Some("queue"))
        .opt("particles", "particles per shard (must match an artifact)", Some("1024"))
        .opt("dim", "dimensionality (must match an artifact)", Some("1"))
        .opt("shards", "independent shards", Some("4"))
        .opt("iters", "iterations per shard", Some("500"))
        .opt("seed", "master seed", Some("42"))
        .opt("scheduler", "sync|async", Some("async"));
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let rt = XlaRuntime::open(Path::new(args.get("artifacts").unwrap()))?;
    let mut cfg = CoordinatorConfig::new(
        args.get("variant").unwrap(),
        args.get_parse("particles", 1024usize)?,
        args.get_parse("dim", 1usize)?,
        args.get_parse("iters", 500u64)?,
    );
    cfg.shards = args.get_parse("shards", 4usize)?;
    cfg.seed = args.get_parse("seed", 42u64)?;
    let scheduler = args.get("scheduler").unwrap_or("async");

    println!(
        "cupso xla: platform={}, variant={}, {} shards × {} particles × {}d, {} iters, {} scheduler",
        rt.platform(),
        cfg.variant,
        cfg.shards,
        cfg.shard_particles,
        cfg.dim,
        cfg.iters,
        scheduler
    );
    let sw = Stopwatch::start();
    let out = match scheduler {
        "sync" => SyncScheduler::run(&rt, &cfg)?,
        "async" => AsyncScheduler::run(&rt, &cfg)?,
        other => bail!("unknown scheduler {other} (sync|async)"),
    };
    let elapsed = sw.elapsed_s();
    println!("gbest fitness : {:.6}", out.gbest_fit);
    println!("wall time     : {elapsed:.3}s");
    println!(
        "chunk calls   : {} ({} iters/shard), merges: {}",
        out.chunk_calls, out.iters_per_shard, out.merges
    );
    println!(
        "shard fits    : {:?}",
        out.shard_fits.iter().map(|f| format!("{f:.1}")).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_info(rest: &[String]) -> Result<()> {
    let spec = Command::new("info", "platform + inventory")
        .opt("artifacts", "artifact directory", Some("artifacts"));
    let args = spec.parse(rest)?;
    println!("cupso {} — cuPSO (SAC'22) reproduction", env!("CARGO_PKG_VERSION"));
    println!(
        "cores: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );
    println!("engines: cpu, reduction, unroll, queue, queuelock (+ xla sync/async)");
    println!("fitness: {}", cupso::fitness::ALL_NAMES.join(", "));
    let dir = Path::new(args.get("artifacts").unwrap());
    match XlaRuntime::open(dir) {
        Ok(rt) => {
            println!("artifacts ({}, jax {}):", rt.platform(), rt.manifest().jax_version);
            for m in rt.manifest().iter() {
                println!(
                    "  {:<28} variant={:<9} n={:<6} d={:<3} k={}",
                    m.name, m.variant, m.n, m.dim, m.iters
                );
            }
        }
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    Ok(())
}
