//! `cupso` — the launcher.
//!
//! Subcommands:
//!   run       solve one PSO workload with a chosen engine
//!   compare   run all five paper algorithms on one workload and rank them
//!   batch     run a multi-job TOML through the shared-pool scheduler
//!             (optionally checkpointing every job into --checkpoint-dir)
//!   resume    continue a suspended/checkpointed batch from its directory
//!   serve     run the scheduler as a live job-service daemon on a Unix
//!             socket and/or TCP (dynamic admission / cancellation / drain)
//!   submit    submit job(s) to a running service
//!   status    show a running service's live jobs and finished results
//!             (`--metrics` prints Prometheus-style telemetry text)
//!   top       live telemetry dashboard for a running service
//!   cancel    cancel a live job on a running service
//!   drain     checkpoint a running service's live jobs and stop it
//!   simulate  print the Plane-C estimated-GPU tables (no execution)
//!   xla       drive the three-layer AOT stack (sync or async coordinator)
//!   info      platform, engines, fitness functions, artifact inventory
//!
//! `cupso <cmd> --help` lists options. A TOML config can seed any run:
//! `cupso run --config run.toml [overrides...]`; `cupso batch` reads a
//! multi-job file (see `config/batch_demo.toml`); `cupso serve` accepts
//! the same file for its scheduler knobs and initial jobs (see
//! `config/service_demo.toml`).

use anyhow::{bail, Context, Result};
use cupso::checkpoint::io::{self as store_io, FaultPlan, FaultyIo};
use cupso::checkpoint::store::{load_snapshot, snapshot_present, SnapshotSink};
use cupso::checkpoint::JobCheckpoint;
use cupso::cli::{split_subcommand, Args, Command};
use cupso::config::{BatchConfig, EngineKind, JobConfig, RunConfig};
use cupso::coordinator::{AsyncScheduler, CoordinatorConfig, SyncScheduler};
use cupso::engine::ParallelSettings;
use cupso::fitness::{by_name, Objective};
use cupso::gpusim;
use cupso::metrics::{AsciiPlot, Stopwatch, Table};
use cupso::pso::PsoParams;
use cupso::rng::RngKind;
use cupso::runtime::XlaRuntime;
use cupso::scheduler::{BatchRun, JobOutcome, JobReport, JobScheduler, JobSpec, SchedPolicy};
use cupso::service::proto::{Json, Request};
use cupso::service::{ServiceEnd, ServiceSession};
use std::path::{Path, PathBuf};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = install_fault_plan().and_then(|()| dispatch(&argv)) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `CUPSO_FAULT_PLAN` (grammar in [`cupso::checkpoint::io`]) swaps the
/// store-IO seam for a deterministic fault injector before any command
/// runs. A plan that fails to parse is fatal: a mistyped plan silently
/// running clean would defeat the crash-testing harness.
fn install_fault_plan() -> Result<()> {
    if let Some(plan) = FaultPlan::from_env() {
        let plan = plan.context("CUPSO_FAULT_PLAN")?;
        eprintln!("cupso: fault injection armed: {} directive(s)", plan.len());
        store_io::install(std::sync::Arc::new(FaultyIo::new(plan)));
    }
    Ok(())
}

fn dispatch(argv: &[String]) -> Result<()> {
    let (cmd, rest) = split_subcommand(argv);
    match cmd {
        Some("run") => cmd_run(rest),
        Some("compare") => cmd_compare(rest),
        Some("batch") => cmd_batch(rest),
        Some("resume") => cmd_resume(rest),
        Some("serve") => cmd_serve(rest),
        Some("submit") => cmd_submit(rest),
        Some("status") => cmd_status(rest),
        Some("top") => cmd_top(rest),
        Some("cancel") => cmd_cancel(rest),
        Some("drain") => cmd_drain(rest),
        Some("simulate") => cmd_simulate(rest),
        Some("xla") => cmd_xla(rest),
        Some("info") => cmd_info(rest),
        Some(other) => bail!("unknown command {other:?}\n\n{}", top_usage()),
        None => {
            println!("{}", top_usage());
            Ok(())
        }
    }
}

fn top_usage() -> String {
    "cupso — queue-based parallel PSO (cuPSO reproduction)\n\n\
     Commands:\n\
     \x20 run       solve one workload with a chosen engine\n\
     \x20 compare   rank all five paper algorithms on one workload\n\
     \x20 batch     run a multi-job TOML on one shared pool\n\
     \x20 resume    continue a checkpointed batch from its directory\n\
     \x20 serve     run the scheduler as a live job-service daemon\n\
     \x20 submit    submit job(s) to a running service\n\
     \x20 status    show a running service's jobs and results\n\
     \x20 top       live telemetry dashboard for a running service\n\
     \x20 cancel    cancel a live job on a running service\n\
     \x20 drain     checkpoint a running service and stop it\n\
     \x20 simulate  print the estimated-GPU tables (Plane C)\n\
     \x20 xla       drive the AOT three-layer stack\n\
     \x20 info      platform + inventory\n\n\
     Try `cupso run --help`."
        .to_string()
}

/// Shared options → RunConfig.
fn run_command_spec(name: &'static str, about: &'static str) -> Command {
    Command::new(name, about)
        .opt("config", "TOML config file (flags override it)", None)
        .opt("fitness", "fitness function", Some("cubic"))
        .opt("particles", "swarm size", Some("1024"))
        .opt("dim", "dimensionality", Some("1"))
        .opt("iters", "iterations", Some("10000"))
        .opt("engine", "cpu|reduction|unroll|queue|queuelock", Some("queuelock"))
        .opt("workers", "worker threads (0 = all cores)", Some("0"))
        .opt("rng", "philox|xoshiro", Some("philox"))
        .opt("seed", "master seed", Some("42"))
        .opt("objective", "max|min (default: function's convention)", None)
        .switch("history", "print the convergence history")
}

fn parse_run_config(rest: &[String], spec: &Command) -> Result<(RunConfig, bool)> {
    let args = spec.parse(rest)?;
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.get("fitness") {
        cfg.fitness = v.to_string();
    }
    cfg.particles = args.get_parse("particles", cfg.particles)?;
    cfg.dim = args.get_parse("dim", cfg.dim)?;
    cfg.iters = args.get_parse("iters", cfg.iters)?;
    if let Some(v) = args.get("engine") {
        cfg.engine = EngineKind::parse(v).with_context(|| format!("bad engine {v}"))?;
    }
    cfg.workers = args.get_parse("workers", cfg.workers)?;
    if let Some(v) = args.get("rng") {
        cfg.rng = RngKind::parse(v).with_context(|| format!("bad rng {v}"))?;
    }
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    if let Some(v) = args.get("objective") {
        cfg.objective = Some(Objective::parse(v).with_context(|| format!("bad objective {v}"))?);
    }
    cfg.validate()?;
    Ok((cfg, args.flag("history")))
}

fn cmd_run(rest: &[String]) -> Result<()> {
    let spec = run_command_spec("run", "solve one PSO workload");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let (cfg, show_history) = parse_run_config(rest, &spec)?;
    let fitness = by_name(&cfg.fitness).unwrap();
    let objective = cfg.objective.unwrap_or(fitness.default_objective());
    let params = PsoParams::from_config(&cfg, fitness.as_ref());
    let mut engine = cupso::engine::build(cfg.engine, cfg.workers)
        .with_context(|| format!("engine {} needs the `xla` subcommand", cfg.engine))?;

    println!(
        "cupso run: {} × {}d × {} iters, engine={}, rng={}, seed={}",
        cfg.particles, cfg.dim, cfg.iters, cfg.engine, cfg.rng, cfg.seed
    );
    let sw = Stopwatch::start();
    let out = engine.run(&params, fitness.as_ref(), objective, cfg.seed);
    let elapsed = sw.elapsed_s();

    println!("gbest fitness  : {:.6}", out.gbest_fit);
    if let Some(opt) = fitness.optimum(cfg.dim) {
        println!("known optimum  : {opt:.6}");
    }
    let pos_preview: Vec<String> = out
        .gbest_pos
        .iter()
        .take(8)
        .map(|p| format!("{p:.4}"))
        .collect();
    println!(
        "gbest position : [{}{}]",
        pos_preview.join(", "),
        if cfg.dim > 8 { ", …" } else { "" }
    );
    println!("wall time      : {elapsed:.3}s");
    println!(
        "counters       : {} pbest improvements, {} queue pushes ({:.4}%), {} gbest updates",
        out.counters.pbest_improvements,
        out.counters.queue_pushes,
        100.0 * out.counters.queue_push_rate(),
        out.counters.gbest_updates
    );
    if show_history {
        let mut t = Table::new("Convergence", &["iteration", "gbest_fit"]);
        for (it, f) in &out.history {
            t.row(&[it.to_string(), format!("{f:.6}")]);
        }
        println!("{}", t.to_markdown());
    }
    Ok(())
}

fn cmd_compare(rest: &[String]) -> Result<()> {
    let spec = run_command_spec("compare", "rank all five paper algorithms");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let (cfg, _) = parse_run_config(rest, &spec)?;
    let fitness = by_name(&cfg.fitness).unwrap();
    let objective = cfg.objective.unwrap_or(fitness.default_objective());
    let params = PsoParams::from_config(&cfg, fitness.as_ref());

    let mut table = Table::new(
        &format!(
            "Engine comparison — {} n={} d={} iters={}",
            cfg.fitness, cfg.particles, cfg.dim, cfg.iters
        ),
        &["Engine", "Time (s)", "gbest", "vs best time"],
    );
    let mut rows = Vec::new();
    for kind in EngineKind::TABLE3 {
        let mut engine = cupso::engine::build(kind, cfg.workers).unwrap();
        let sw = Stopwatch::start();
        let out = engine.run(&params, fitness.as_ref(), objective, cfg.seed);
        rows.push((kind.label(), sw.elapsed_s(), out.gbest_fit));
    }
    let best = rows.iter().map(|r| r.1).fold(f64::INFINITY, f64::min);
    for (name, t, fit) in rows {
        table.row(&[
            name.to_string(),
            format!("{t:.3}"),
            format!("{fit:.3}"),
            format!("{:.2}x", t / best),
        ]);
    }
    println!("{}", table.to_markdown());
    Ok(())
}

/// Apply the scheduler-knob CLI overrides shared by `batch` and `serve`.
fn apply_scheduler_overrides(cfg: &mut BatchConfig, args: &Args) -> Result<()> {
    if let Some(w) = args.get("workers") {
        cfg.workers = w
            .parse()
            .map_err(|e| anyhow::anyhow!("--workers {w:?}: {e}"))?;
    }
    if let Some(p) = args.get("policy") {
        cfg.policy = p.to_string();
    }
    if let Some(s) = args.get("streams") {
        cfg.streams = s
            .parse()
            .map_err(|e| anyhow::anyhow!("--streams {s:?}: {e}"))?;
    }
    if let Some(b) = args.get("batch-steps") {
        cfg.batch_steps = b
            .parse()
            .map_err(|e| anyhow::anyhow!("--batch-steps {b:?}: {e}"))?;
    }
    if let Some(q) = args.get("preempt-quantum") {
        cfg.preempt_quantum = q
            .parse()
            .map_err(|e| anyhow::anyhow!("--preempt-quantum {q:?}: {e}"))?;
    }
    if args.flag("pack") {
        cfg.pack = true;
    }
    if let Some(n) = args.get("pack-min") {
        cfg.pack_min = n
            .parse()
            .map_err(|e| anyhow::anyhow!("--pack-min {n:?}: {e}"))?;
    }
    if let Some(n) = args.get("pack-max") {
        cfg.pack_max = n
            .parse()
            .map_err(|e| anyhow::anyhow!("--pack-max {n:?}: {e}"))?;
    }
    if cfg.streams == 0 || cfg.batch_steps == 0 {
        bail!("--streams and --batch-steps must be >= 1");
    }
    if cfg.pack_min < 2 {
        bail!("--pack-min must be >= 2 (a pack of one is a standalone job)");
    }
    if cfg.pack_max != 0 && cfg.pack_max < cfg.pack_min {
        bail!("--pack-max must be 0 (unbounded) or >= --pack-min");
    }
    Ok(())
}

/// Build a scheduler from batch-config knobs.
fn scheduler_from_knobs(cfg: &BatchConfig) -> Result<(JobScheduler, SchedPolicy)> {
    let policy = SchedPolicy::parse(&cfg.policy)
        .with_context(|| format!("bad policy {:?} (round-robin|edf|weighted-fair)", cfg.policy))?;
    let scheduler = JobScheduler::new(ParallelSettings::with_streams(cfg.workers, cfg.streams))
        .policy(policy)
        .batch_steps(cfg.batch_steps)
        .preempt_quantum(cfg.preempt_quantum)
        .pack(cfg.pack)
        .pack_min(cfg.pack_min)
        .pack_max(cfg.pack_max);
    Ok((scheduler, policy))
}

fn cmd_batch(rest: &[String]) -> Result<()> {
    let spec = Command::new("batch", "run a multi-job TOML on one shared pool")
        .opt("config", "multi-job TOML file", Some("config/batch_demo.toml"))
        .opt("workers", "worker threads (0 = all cores; overrides the file)", None)
        .opt("policy", "round-robin|edf|weighted-fair (overrides the file)", None)
        .opt("streams", "concurrent pool streams (overrides the file)", None)
        .opt("batch-steps", "iterations per job per round (overrides the file)", None)
        .opt(
            "preempt-quantum",
            "suspend a job to a checkpoint after this many steps when jobs \
             outnumber streams; 0 = cooperative (overrides the file)",
            None,
        )
        .switch("pack", "fuse compatible Queue jobs into shared-slab packs")
        .opt("pack-min", "smallest group worth packing (>= 2; overrides the file)", None)
        .opt("pack-max", "largest pack formed (0 = unbounded; overrides the file)", None)
        .opt(
            "checkpoint-dir",
            "write periodic per-job checkpoints here (enables `cupso resume`)",
            None,
        )
        .opt(
            "checkpoint-every",
            "scheduling rounds between periodic checkpoints",
            Some("64"),
        )
        .opt(
            "checkpoint-keep",
            "retained snapshots: 1 = overwrite in place, N > 1 = rotate \
             snap_<seq>/ directories keeping the latest N",
            Some("1"),
        )
        .opt(
            "suspend-after",
            "suspend the whole batch to --checkpoint-dir after this many rounds and exit",
            None,
        )
        .switch("trace", "print every global-best improvement as it lands");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let mut cfg = BatchConfig::from_file(Path::new(args.get("config").unwrap()))?;
    apply_scheduler_overrides(&mut cfg, &args)?;
    let trace = args.flag("trace");
    let ckpt_dir = args.get("checkpoint-dir").map(PathBuf::from);
    let every: u64 = args.get_parse("checkpoint-every", 64u64)?;
    let keep: usize = args.get_parse("checkpoint-keep", 1usize)?;
    let suspend_after: Option<u64> = args
        .get("suspend-after")
        .map(|s| {
            s.parse()
                .map_err(|e| anyhow::anyhow!("--suspend-after {s:?}: {e}"))
        })
        .transpose()?;
    if every == 0 {
        bail!("--checkpoint-every must be >= 1");
    }
    if keep == 0 {
        bail!("--checkpoint-keep must be >= 1");
    }
    if suspend_after.is_some() && ckpt_dir.is_none() {
        bail!("--suspend-after requires --checkpoint-dir");
    }

    let specs: Vec<JobSpec> = cfg
        .jobs
        .iter()
        .map(JobSpec::from_config)
        .collect::<Result<_>>()?;
    let (scheduler, policy) = scheduler_from_knobs(&cfg)?;
    println!(
        "cupso batch: {} jobs, {} policy, {} pool workers, {} streams, {} steps/round{}",
        specs.len(),
        policy,
        scheduler.pool().workers(),
        scheduler.streams(),
        cfg.batch_steps,
        if cfg.preempt_quantum > 0 {
            format!(", preemption quantum {}", cfg.preempt_quantum)
        } else {
            String::new()
        }
    );

    // One JobReport per stepped job per scheduling round (so with
    // --streams > 1 several reports share a round).
    let mut reports = 0u64;
    let mut improvements = 0u64;
    let sw = Stopwatch::start();
    let mut telemetry = |r: &JobReport<'_>| {
        reports += 1;
        if r.improved {
            improvements += 1;
            if trace {
                println!("  [{}] iter {:>6}  gbest {:.6}", r.name, r.iter, r.gbest_fit);
            }
        }
    };
    let outcomes = match &ckpt_dir {
        None => scheduler.run_with(&specs, &mut telemetry)?,
        Some(dir) => {
            let completed = drive_session(
                &scheduler,
                &specs,
                &cfg,
                dir,
                every,
                keep,
                suspend_after,
                None,
                &mut telemetry,
            )?;
            match completed {
                Some(outcomes) => outcomes,
                None => return Ok(()), // suspended on request; message printed
            }
        }
    };
    let elapsed = sw.elapsed_s();
    print_batch_results(&outcomes, &specs, elapsed, reports, improvements);
    Ok(())
}

/// Continue a checkpointed batch: `cupso resume <dir>` reconstructs the
/// jobs and scheduler from the directory `cupso batch --checkpoint-dir`
/// (or a drained `cupso serve`) wrote, restores every job and runs the
/// batch to termination — bit-identically to the never-interrupted batch
/// for the deterministic engines.
fn cmd_resume(rest: &[String]) -> Result<()> {
    let spec = Command::new("resume", "continue a checkpointed batch from its directory")
        .opt(
            "checkpoint-every",
            "scheduling rounds between refreshed checkpoints",
            Some("64"),
        )
        .switch("trace", "print every global-best improvement as it lands");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        println!("usage: cupso resume <checkpoint-dir>");
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let dir = args
        .positional
        .first()
        .map(PathBuf::from)
        .context("usage: cupso resume <checkpoint-dir>")?;
    let every: u64 = args.get_parse("checkpoint-every", 64u64)?;
    if every == 0 {
        bail!("--checkpoint-every must be >= 1");
    }
    let trace = args.flag("trace");

    let loaded = load_snapshot(&dir)?;
    loaded.report();
    let (knobs, keep, ckpts) = (loaded.knobs, loaded.keep, loaded.jobs);
    let specs = ckpts
        .iter()
        .map(JobSpec::from_checkpoint)
        .collect::<Result<Vec<_>>>()?;
    let (scheduler, policy) = scheduler_from_knobs(&knobs)
        .with_context(|| format!("manifest of {}", loaded.dir.display()))?;
    let done = ckpts.iter().filter(|c| c.stop.is_some()).count();
    println!(
        "cupso resume: {} jobs from {} ({} already finished), {} policy, {} streams",
        specs.len(),
        dir.display(),
        done,
        policy,
        scheduler.streams()
    );

    let mut reports = 0u64;
    let mut improvements = 0u64;
    let sw = Stopwatch::start();
    let mut telemetry = |r: &JobReport<'_>| {
        reports += 1;
        if r.improved {
            improvements += 1;
            if trace {
                println!("  [{}] iter {:>6}  gbest {:.6}", r.name, r.iter, r.gbest_fit);
            }
        }
    };
    let outcomes = drive_session(
        &scheduler,
        &specs,
        &knobs,
        &dir,
        every,
        keep,
        None,
        Some(ckpts),
        &mut telemetry,
    )?
    .expect("resume without --suspend-after runs to completion");
    let elapsed = sw.elapsed_s();
    print_batch_results(&outcomes, &specs, elapsed, reports, improvements);
    Ok(())
}

/// Single-session driver shared by `batch --checkpoint-dir` and `resume`:
/// run ONE scheduler session with the in-place persistence hook — every
/// `every` rounds a full snapshot is written while the batch keeps
/// running (no suspend/restore churn, no buffer reallocation). `Ok(None)`
/// means the batch was deliberately suspended (`suspend_after`, final
/// snapshot written); `Ok(Some(outcomes))` means it completed.
#[allow(clippy::too_many_arguments)]
fn drive_session<F: FnMut(&JobReport<'_>)>(
    scheduler: &JobScheduler,
    specs: &[JobSpec],
    cfg: &BatchConfig,
    dir: &Path,
    every: u64,
    keep: usize,
    suspend_after: Option<u64>,
    resume: Option<Vec<JobCheckpoint>>,
    telemetry: F,
) -> Result<Option<Vec<JobOutcome>>> {
    let mut sink = SnapshotSink::new(dir, cfg, keep, "batch")?;
    let batch = scheduler.run_session_with(
        specs,
        resume.as_deref(),
        suspend_after,
        Some(every),
        |snap| sink.persist(snap),
        telemetry,
    )?;
    match batch {
        BatchRun::Complete(outcomes) => Ok(Some(outcomes)),
        BatchRun::Suspended(snap) => {
            sink.persist(&snap)?;
            println!(
                "suspended {} jobs into {} — continue with `cupso resume {}`",
                snap.len(),
                dir.display(),
                dir.display()
            );
            Ok(None)
        }
    }
}

fn print_batch_results(
    outcomes: &[JobOutcome],
    specs: &[JobSpec],
    elapsed: f64,
    reports: u64,
    improvements: u64,
) {
    // A telemetry report covers a whole round (batch_steps iterations),
    // so iteration throughput comes from the outcomes, not the report
    // count.
    let total_steps: u64 = outcomes.iter().map(|o| o.steps).sum();
    let mut table = Table::new(
        "Batch results",
        &["Job", "Engine", "Workload", "Steps", "Stop", "gbest"],
    );
    for (o, s) in outcomes.iter().zip(specs) {
        table.row(&[
            o.name.to_string(),
            o.engine.label().to_string(),
            format!("{}x{}d", s.params.n, s.params.dim),
            o.steps.to_string(),
            o.stop.to_string(),
            format!("{:.6}", o.output.gbest_fit),
        ]);
    }
    println!("{}", table.to_markdown());
    println!(
        "aggregate: {} jobs in {:.3}s — {:.1} jobs/s, {} steps ({:.0} steps/s), \
         {} job-reports ({} improving)",
        outcomes.len(),
        elapsed,
        outcomes.len() as f64 / elapsed.max(1e-9),
        total_steps,
        total_steps as f64 / elapsed.max(1e-9),
        reports,
        improvements
    );
}

// --------------------------------------------------------------------
// The service verbs: serve (daemon) + submit/status/cancel/drain
// (clients of the line-JSON protocol over a Unix socket or TCP; see
// service/proto.rs and service/server.rs).
// --------------------------------------------------------------------

fn cmd_serve(rest: &[String]) -> Result<()> {
    let spec = Command::new("serve", "run the scheduler as a live job-service daemon")
        .opt("socket", "Unix socket path to listen on", None)
        .opt("listen", "TCP host:port to listen on (combinable with --socket)", None)
        .opt(
            "max-conns",
            "concurrent client connection cap; excess clients are shed loudly",
            None,
        )
        .opt(
            "config",
            "batch TOML seeding the scheduler knobs and initial jobs",
            None,
        )
        .opt("workers", "worker threads (0 = all cores; overrides the file)", None)
        .opt("policy", "round-robin|edf|weighted-fair (overrides the file)", None)
        .opt("streams", "concurrent pool streams (overrides the file)", None)
        .opt("batch-steps", "iterations per job per round (overrides the file)", None)
        .opt(
            "preempt-quantum",
            "preemption quantum in steps; 0 = cooperative (overrides the file)",
            None,
        )
        .switch("pack", "fuse compatible Queue jobs into shared-slab packs")
        .opt("pack-min", "smallest group worth packing (>= 2; overrides the file)", None)
        .opt("pack-max", "largest pack formed (0 = unbounded; overrides the file)", None)
        .opt(
            "quota-jobs",
            "per-tenant concurrent-job cap; 0 = unlimited (overrides the file)",
            None,
        )
        .opt(
            "quota-steps",
            "per-tenant live iteration-budget cap; 0 = unlimited (overrides the file)",
            None,
        )
        .opt(
            "checkpoint-dir",
            "snapshot directory: drain target, periodic live snapshots, and \
             warm-restart source (enables `cupso resume`)",
            None,
        )
        .opt(
            "checkpoint-every",
            "rounds between periodic live snapshots into --checkpoint-dir; \
             0 = snapshot only on drain (overrides the file)",
            None,
        )
        .opt(
            "checkpoint-keep",
            "retained snapshots: 1 = overwrite in place, N > 1 = rotate \
             snap_<seq>/ directories keeping the latest N (overrides the file)",
            None,
        )
        .opt(
            "trace-dump",
            "append flight-recorder trace dumps (panic/fatal persist/drain) \
             to this file instead of stderr (overrides the file)",
            None,
        )
        .switch(
            "no-telemetry",
            "disable runtime metrics and the trace ring entirely",
        )
        .switch("trace", "print every global-best improvement as it lands");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let socket = args.get("socket").map(PathBuf::from);
    let listen = args.get("listen").map(str::to_string);
    if socket.is_none() && listen.is_none() {
        bail!(
            "--socket <path> and/or --listen <host:port> is required \
             (e.g. --socket /tmp/cupso.sock)"
        );
    }
    let max_conns: usize = match args.get("max-conns") {
        Some(v) => {
            let n = v
                .parse()
                .map_err(|e| anyhow::anyhow!("--max-conns {v:?}: {e}"))?;
            if n == 0 {
                bail!("--max-conns must be >= 1");
            }
            n
        }
        None => cupso::service::DEFAULT_MAX_CONNS,
    };
    let mut cfg = match args.get("config") {
        // Service configs may be scheduler-knobs-only: every job can
        // arrive live through `cupso submit`.
        Some(path) => BatchConfig::from_file_for_service(Path::new(path))?,
        None => BatchConfig {
            workers: 0,
            policy: "round-robin".into(),
            streams: 1,
            batch_steps: 1,
            preempt_quantum: 0,
            pack: false,
            pack_min: 2,
            pack_max: 0,
            quota_jobs: 0,
            quota_steps: 0,
            checkpoint_every: 0,
            checkpoint_keep: 1,
            telemetry: true,
            trace_dump: None,
            jobs: Vec::new(),
        },
    };
    apply_scheduler_overrides(&mut cfg, &args)?;
    if let Some(v) = args.get("quota-jobs") {
        cfg.quota_jobs = v
            .parse()
            .map_err(|e| anyhow::anyhow!("--quota-jobs {v:?}: {e}"))?;
    }
    if let Some(v) = args.get("quota-steps") {
        cfg.quota_steps = v
            .parse()
            .map_err(|e| anyhow::anyhow!("--quota-steps {v:?}: {e}"))?;
    }
    if let Some(v) = args.get("checkpoint-every") {
        cfg.checkpoint_every = v
            .parse()
            .map_err(|e| anyhow::anyhow!("--checkpoint-every {v:?}: {e}"))?;
    }
    if let Some(v) = args.get("checkpoint-keep") {
        cfg.checkpoint_keep = v
            .parse()
            .map_err(|e| anyhow::anyhow!("--checkpoint-keep {v:?}: {e}"))?;
        if cfg.checkpoint_keep == 0 {
            bail!("--checkpoint-keep must be >= 1");
        }
    }
    if let Some(path) = args.get("trace-dump") {
        cfg.trace_dump = Some(path.to_string());
    }
    if args.flag("no-telemetry") {
        cfg.telemetry = false;
    }
    // Telemetry is wired before the session exists so even the initial
    // jobs' admissions land in the flight recorder, and the panic hook
    // guarantees a crashing daemon dumps the trace ring on the way out.
    cupso::telemetry::set_enabled(cfg.telemetry);
    cupso::telemetry::set_trace_path(cfg.trace_dump.as_ref().map(PathBuf::from));
    cupso::telemetry::install_panic_hook();
    let (scheduler, policy) = scheduler_from_knobs(&cfg)?;
    let ckpt_dir = args.get("checkpoint-dir").map(PathBuf::from);
    if cfg.checkpoint_every > 0 && ckpt_dir.is_none() {
        bail!("--checkpoint-every requires --checkpoint-dir (snapshots need a home)");
    }

    // Warm restart: a committed snapshot in the checkpoint directory
    // means a previous serve died mid-run (or was drained) — adopt its
    // jobs instead of starting cold, so a supervisor restart loop is a
    // correct recovery story. Initial config jobs whose names were
    // adopted are skipped: the snapshot is the newer truth about them.
    let warm = match &ckpt_dir {
        Some(dir) if snapshot_present(dir) => {
            let loaded = load_snapshot(dir)?;
            loaded.report();
            Some(loaded)
        }
        _ => None,
    };
    let adopted_names: std::collections::HashSet<&str> = warm
        .as_ref()
        .map(|l| l.jobs.iter().map(|c| &*c.name).collect())
        .unwrap_or_default();
    let initial: Vec<JobSpec> = cfg
        .jobs
        .iter()
        .filter(|j| !adopted_names.contains(j.name.as_str()))
        .map(JobSpec::from_config)
        .collect::<Result<_>>()?;
    let knobs = BatchConfig {
        jobs: Vec::new(),
        ..cfg.clone()
    };

    let (mut service, handle) =
        ServiceSession::new(&scheduler, knobs, ckpt_dir.clone(), initial)?;
    if let Some(loaded) = &warm {
        let live = service.adopt(&loaded.jobs)?;
        println!(
            "cupso serve: warm restart — adopted {} job(s) from {} ({} still live)",
            loaded.jobs.len(),
            loaded.dir.display(),
            live
        );
    }
    let mut listeners = Vec::new();
    let mut endpoints = Vec::new();
    if let Some(path) = &socket {
        listeners.push(cupso::service::Listener::Unix(cupso::service::bind(path)?));
        endpoints.push(path.display().to_string());
    }
    if let Some(addr) = &listen {
        listeners.push(cupso::service::Listener::Tcp(cupso::service::bind_tcp(addr)?));
        endpoints.push(format!("tcp {addr}"));
    }
    let _accept = cupso::service::spawn_server_on(listeners, handle, max_conns);
    println!(
        "cupso serve: listening on {} — {} initial jobs, {} policy, {} streams, {} steps/round, \
         {} conns max{}{}{}",
        endpoints.join(" + "),
        cfg.jobs.len(),
        policy,
        scheduler.streams(),
        cfg.batch_steps,
        max_conns,
        if cfg.quota_jobs > 0 || cfg.quota_steps > 0 {
            format!(
                ", tenant quotas {} jobs / {} steps",
                cfg.quota_jobs, cfg.quota_steps
            )
        } else {
            String::new()
        },
        if cfg.preempt_quantum > 0 {
            format!(", preemption quantum {}", cfg.preempt_quantum)
        } else {
            String::new()
        },
        match (&ckpt_dir, cfg.checkpoint_every) {
            (Some(d), 0) => format!(", drain dir {}", d.display()),
            (Some(d), n) => format!(
                ", snapshot dir {} (every {} rounds, keep {})",
                d.display(),
                n,
                cfg.checkpoint_keep
            ),
            (None, _) => ", no drain dir (drain of live jobs refused)".to_string(),
        }
    );
    match (&socket, &listen) {
        (Some(path), _) => println!(
            "  submit with `cupso submit --socket {} --name my-job ...`",
            path.display()
        ),
        (None, Some(addr)) => {
            println!("  submit with `cupso submit --connect {addr} --name my-job ...`")
        }
        (None, None) => unreachable!("at least one endpoint is required above"),
    }

    let trace = args.flag("trace");
    let end = service.run_with(|r| {
        if trace && r.improved {
            println!("  [{}] iter {:>6}  gbest {:.6}", r.name, r.iter, r.gbest_fit);
        }
    })?;
    // Best-effort socket cleanup: a stale file is also handled at the
    // next bind, but leaving none behind is tidier.
    if let Some(path) = &socket {
        let _ = std::fs::remove_file(path);
    }
    print_service_results(&end);
    Ok(())
}

fn print_service_results(end: &ServiceEnd) {
    if !end.results.is_empty() {
        let mut table = Table::new(
            "Service results",
            &["Job", "Engine", "Steps", "Stop", "gbest"],
        );
        for o in &end.results {
            table.row(&[
                o.name.clone(),
                o.engine.label().to_string(),
                o.steps.to_string(),
                o.stop.to_string(),
                format!("{:.6}", o.gbest_fit),
            ]);
        }
        println!("{}", table.to_markdown());
    }
    match &end.snapshot_dir {
        Some(dir) => println!(
            "drained {} live jobs into {} — continue with `cupso resume {}`",
            end.drained,
            dir.display(),
            dir.display()
        ),
        None => println!(
            "service stopped: {} finished jobs, no live jobs to drain",
            end.finished_total
        ),
    }
}

/// Where a client verb reaches the daemon: the two transports speak
/// the identical line-JSON protocol, so everything past connect() is
/// shared.
enum ServiceAddr {
    Unix(PathBuf),
    Tcp(String),
}

/// `--socket <path>` or `--connect <host:port>` — exactly one.
fn service_addr(args: &Args) -> Result<ServiceAddr> {
    match (args.get("socket"), args.get("connect")) {
        (Some(_), Some(_)) => {
            bail!("pass either --socket <path> or --connect <host:port>, not both")
        }
        (Some(path), None) => Ok(ServiceAddr::Unix(PathBuf::from(path))),
        (None, Some(addr)) => Ok(ServiceAddr::Tcp(addr.to_string())),
        (None, None) => bail!("--socket <path> or --connect <host:port> is required"),
    }
}

/// Send one request line to a running service and parse its response,
/// failing loudly on transport problems or an `"ok": false` reply.
fn service_roundtrip(addr: &ServiceAddr, request: &Request) -> Result<Json> {
    let line = match addr {
        ServiceAddr::Unix(path) => {
            let stream = std::os::unix::net::UnixStream::connect(path).with_context(|| {
                format!(
                    "connecting to {} (is `cupso serve` running there?)",
                    path.display()
                )
            })?;
            exchange_line(stream, request)?
        }
        ServiceAddr::Tcp(addr) => {
            let stream = std::net::TcpStream::connect(addr).with_context(|| {
                format!("connecting to tcp {addr} (is `cupso serve --listen` running there?)")
            })?;
            let _ = stream.set_nodelay(true);
            exchange_line(stream, request)?
        }
    };
    if line.trim().is_empty() {
        bail!("service closed the connection without a response");
    }
    let doc = Json::parse(line.trim())?;
    let ok = doc
        .get("ok")
        .context("response carries no \"ok\" field")?
        .as_bool("ok")?;
    if !ok {
        bail!(
            "service error: {}",
            doc.str_field("error").unwrap_or("unknown")
        );
    }
    Ok(doc)
}

/// One request line out, one response line back, on any stream. The
/// write completes before the read starts, so no clone is needed.
fn exchange_line<S: std::io::Read + std::io::Write>(
    mut stream: S,
    request: &Request,
) -> Result<String> {
    use std::io::{BufRead, BufReader, Write};
    writeln!(stream, "{}", request.render()).context("sending request")?;
    stream.flush().context("flushing request")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading response")?;
    Ok(line)
}

fn cmd_submit(rest: &[String]) -> Result<()> {
    let spec = Command::new("submit", "submit job(s) to a running service")
        .opt("socket", "service Unix socket path", None)
        .opt("connect", "service TCP host:port (alternative to --socket)", None)
        .opt(
            "config",
            "batch TOML whose [jobs.*] sections are all submitted (per-job flags ignored)",
            None,
        )
        .opt("name", "job name (unique identity key; required without --config)", None)
        .opt("tenant", "tenant label for per-tenant admission quotas", None)
        .opt("fitness", "fitness function", Some("cubic"))
        .opt("particles", "swarm size", Some("1024"))
        .opt("dim", "dimensionality", Some("1"))
        .opt("iters", "iteration budget", Some("1000"))
        .opt("engine", "cpu|reduction|unroll|queue|queuelock|async", Some("queuelock"))
        .opt("vmax-frac", "velocity clamp fraction", Some("0.5"))
        .opt("seed", "master seed", Some("42"))
        .opt("objective", "max|min (default: function's convention)", None)
        .opt("target-fitness", "early stop: target fitness", None)
        .opt("stall-window", "early stop: non-improving steps", None)
        .opt("max-steps", "early stop: scheduler-step cap", None)
        .opt("deadline", "EDF deadline in steps", None)
        .opt(
            "retries",
            "retry transient connect/submit failures this many times \
             (capped exponential backoff; idempotent via the job name)",
            Some("0"),
        );
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let addr = service_addr(&args)?;
    let jobs: Vec<JobConfig> = match args.get("config") {
        Some(path) => BatchConfig::from_file(Path::new(path))?.jobs,
        None => {
            let name = args
                .get("name")
                .context("--name is required (or use --config)")?;
            let mut job = JobConfig::with_defaults(name);
            if let Some(v) = args.get("fitness") {
                job.fitness = v.to_string();
            }
            if let Some(v) = args.get("tenant") {
                job.tenant = Some(v.to_string());
            }
            job.particles = args.get_parse("particles", job.particles)?;
            job.dim = args.get_parse("dim", job.dim)?;
            job.iters = args.get_parse("iters", job.iters)?;
            if let Some(v) = args.get("engine") {
                job.engine = EngineKind::parse(v).with_context(|| format!("bad engine {v}"))?;
            }
            job.vmax_frac = args.get_parse("vmax-frac", job.vmax_frac)?;
            job.seed = args.get_parse("seed", job.seed)?;
            if let Some(v) = args.get("objective") {
                job.objective =
                    Some(Objective::parse(v).with_context(|| format!("bad objective {v}"))?);
            }
            if let Some(v) = args.get("target-fitness") {
                job.target_fitness = Some(
                    v.parse()
                        .map_err(|e| anyhow::anyhow!("--target-fitness {v:?}: {e}"))?,
                );
            }
            if let Some(v) = args.get("stall-window") {
                job.stall_window = Some(
                    v.parse()
                        .map_err(|e| anyhow::anyhow!("--stall-window {v:?}: {e}"))?,
                );
            }
            if let Some(v) = args.get("max-steps") {
                job.max_steps = Some(
                    v.parse()
                        .map_err(|e| anyhow::anyhow!("--max-steps {v:?}: {e}"))?,
                );
            }
            if let Some(v) = args.get("deadline") {
                job.deadline = Some(
                    v.parse()
                        .map_err(|e| anyhow::anyhow!("--deadline {v:?}: {e}"))?,
                );
            }
            job.validate()?;
            vec![job]
        }
    };
    let retries: u32 = args.get_parse("retries", 0u32)?;
    for job in &jobs {
        let Some(doc) = submit_with_retries(&addr, job, retries)? else {
            continue; // an earlier attempt landed; message already printed
        };
        println!(
            "submitted {} → slot {}, stream {}",
            doc.str_field("name")?,
            doc.get("slot").context("missing slot")?.as_u64("slot")?,
            doc.get("stream").context("missing stream")?.as_u64("stream")?,
        );
    }
    Ok(())
}

/// Submit one job, retrying transient failures (connection refused,
/// dropped mid-exchange, service momentarily overloaded) with capped
/// exponential backoff: 50ms doubling to a 2s ceiling. The retry loop is
/// idempotent through the job's unique name — if an earlier attempt
/// actually landed before its response was lost, the service refuses the
/// duplicate name and that refusal on a retry counts as success
/// (`Ok(None)`).
fn submit_with_retries(
    addr: &ServiceAddr,
    job: &JobConfig,
    retries: u32,
) -> Result<Option<Json>> {
    let cap = std::time::Duration::from_secs(2);
    let mut delay = std::time::Duration::from_millis(50);
    let mut attempt = 0u32;
    loop {
        match service_roundtrip(addr, &Request::Submit(job.clone())) {
            Ok(doc) => return Ok(Some(doc)),
            // The duplicate-name refusal is only a success signal when a
            // previous attempt could have landed; on the first try it is
            // a genuine error.
            Err(e) if attempt > 0 && format!("{e:#}").contains("unique identity keys") => {
                println!(
                    "submitted {} on an earlier attempt (service already holds the name)",
                    job.name
                );
                return Ok(None);
            }
            Err(e) if attempt < retries => {
                attempt += 1;
                eprintln!(
                    "cupso submit: {} attempt {}/{} failed ({e:#}); retrying in {}ms",
                    job.name,
                    attempt,
                    retries,
                    delay.as_millis()
                );
                std::thread::sleep(delay);
                delay = cap.min(delay * 2);
            }
            Err(e) => return Err(e),
        }
    }
}

fn cmd_status(rest: &[String]) -> Result<()> {
    let spec = Command::new("status", "show a running service's jobs and results")
        .opt("socket", "service Unix socket path", None)
        .opt("connect", "service TCP host:port (alternative to --socket)", None)
        .switch(
            "metrics",
            "print Prometheus-style telemetry text (the `metrics` verb) \
             instead of the job tables",
        )
        .switch("json", "print the raw JSON response line");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let addr = service_addr(&args)?;
    if args.flag("metrics") {
        let doc = service_roundtrip(&addr, &Request::Metrics)?;
        if args.flag("json") {
            println!("{}", doc.render());
        } else {
            let m = doc.get("metrics").context("response missing metrics")?;
            print!("{}", render_prometheus(m)?);
        }
        return Ok(());
    }
    let doc = service_roundtrip(&addr, &Request::Status)?;
    if args.flag("json") {
        // Re-render the parsed document for scripting (same writer the
        // daemon used, so the line cannot drift from the wire format).
        println!("{}", doc.render());
        return Ok(());
    }
    let rounds = doc.get("rounds").context("missing rounds")?.as_u64("rounds")?;
    let streams = doc.get("streams").context("missing streams")?.as_u64("streams")?;
    let finished_total = doc
        .get("finished_total")
        .context("missing finished_total")?
        .as_u64("finished_total")?;
    let live = json_rows(&doc, "live")?;
    let finished = json_rows(&doc, "finished")?;
    println!(
        "cupso status: round {rounds}, {streams} streams, {} live, {finished_total} finished",
        live.len()
    );
    let uptime = doc.get("uptime_s").context("missing uptime_s")?.as_u64("uptime_s")?;
    let admitted = doc
        .get("admitted_total")
        .context("missing admitted_total")?
        .as_u64("admitted_total")?;
    let cancelled = doc
        .get("cancelled_total")
        .context("missing cancelled_total")?
        .as_u64("cancelled_total")?;
    let shed = doc
        .get("shed_total")
        .context("missing shed_total")?
        .as_u64("shed_total")?;
    println!(
        "  uptime {uptime}s — lifetime {admitted} admitted / {finished_total} finished / \
         {cancelled} cancelled / {shed} conns shed; last snapshot {}",
        fmt_age(doc.num_or_null_field("last_snapshot_age_s")?)
    );
    if !live.is_empty() {
        let mut t = Table::new(
            "Live jobs",
            &["Job", "Engine", "Steps", "Budget", "gbest", "Stream"],
        );
        for j in &live {
            t.row(&[
                j.str_field("name")?.to_string(),
                j.str_field("engine")?.to_string(),
                j.get("steps").context("steps")?.as_u64("steps")?.to_string(),
                j.get("max_iter").context("max_iter")?.as_u64("max_iter")?.to_string(),
                fmt_gbest(j.num_or_null_field("gbest")?),
                j.get("stream").context("stream")?.as_u64("stream")?.to_string(),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if !finished.is_empty() {
        let mut t = Table::new("Finished jobs", &["Job", "Engine", "Steps", "Stop", "gbest"]);
        for j in &finished {
            t.row(&[
                j.str_field("name")?.to_string(),
                j.str_field("engine")?.to_string(),
                j.get("steps").context("steps")?.as_u64("steps")?.to_string(),
                j.str_field("stop")?.to_string(),
                fmt_gbest(j.num_or_null_field("gbest")?),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    Ok(())
}

/// Render a wire `gbest` that may be `null`: JSON numbers cannot carry
/// non-finite values, and a just-admitted job legitimately reports one
/// (-inf under maximize, +inf under minimize) before its first
/// improving round.
fn fmt_gbest(value: Option<f64>) -> String {
    match value {
        Some(g) => format!("{g:.6}"),
        None => "n/a".to_string(),
    }
}

/// Rows of an array field of a parsed response.
fn json_rows<'a>(doc: &'a Json, key: &str) -> Result<Vec<&'a Json>> {
    match doc.get(key) {
        Some(Json::Arr(items)) => Ok(items.iter().collect()),
        Some(other) => bail!("{key}: expected array, got {other:?}"),
        None => bail!("response missing {key:?}"),
    }
}

/// Key/value fields of an object-valued field of a parsed response.
fn obj_fields<'a>(doc: &'a Json, key: &str) -> Result<&'a [(String, Json)]> {
    match doc.get(key) {
        Some(Json::Obj(fields)) => Ok(fields),
        Some(other) => bail!("{key}: expected object, got {other:?}"),
        None => bail!("response missing {key:?}"),
    }
}

/// Render a wire age-in-seconds that may be `null` (never happened).
fn fmt_age(age: Option<f64>) -> String {
    match age {
        Some(a) => format!("{a:.0}s ago"),
        None => "never".to_string(),
    }
}

/// Render a parsed `metrics` body as Prometheus-style exposition text.
/// The wire carries structured JSON (scripting-friendly, one parser);
/// the text form is a client-side view of the same snapshot, so the
/// two can never disagree.
fn render_prometheus(m: &Json) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    let uptime = m.get("uptime_s").context("metrics missing uptime_s")?.as_u64("uptime_s")?;
    let _ = writeln!(out, "# TYPE cupso_uptime_seconds gauge");
    let _ = writeln!(out, "cupso_uptime_seconds {uptime}");
    if let Some(age) = m.num_or_null_field("last_snapshot_age_s")? {
        let _ = writeln!(out, "# TYPE cupso_last_snapshot_age_seconds gauge");
        let _ = writeln!(out, "cupso_last_snapshot_age_seconds {age:.0}");
    }
    for (k, v) in obj_fields(m, "counters")? {
        let _ = writeln!(out, "# TYPE cupso_{k} counter");
        let _ = writeln!(out, "cupso_{k} {}", v.as_u64(k)?);
    }
    for (k, v) in obj_fields(m, "gauges")? {
        let _ = writeln!(out, "# TYPE cupso_{k} gauge");
        let _ = writeln!(out, "cupso_{k} {}", v.as_u64(k)?);
    }
    for (k, h) in obj_fields(m, "histos")? {
        let count = h.get("count").with_context(|| format!("{k}.count"))?.as_u64("count")?;
        let sum = h.get("sum").with_context(|| format!("{k}.sum"))?.as_u64("sum")?;
        let max = h.get("max").with_context(|| format!("{k}.max"))?.as_u64("max")?;
        let _ = writeln!(out, "# TYPE cupso_{k} summary");
        let _ = writeln!(out, "cupso_{k}_count {count}");
        let _ = writeln!(out, "cupso_{k}_sum {sum}");
        let _ = writeln!(out, "cupso_{k}_max {max}");
    }
    Ok(out)
}

fn cmd_top(rest: &[String]) -> Result<()> {
    let spec = Command::new("top", "live telemetry dashboard for a running service")
        .opt("socket", "service Unix socket path", None)
        .opt("connect", "service TCP host:port (alternative to --socket)", None)
        .opt("interval-ms", "milliseconds between refreshes", Some("1000"))
        .opt(
            "samples",
            "render this many frames then exit; 0 = until interrupted",
            Some("0"),
        )
        .switch("plain", "do not clear the screen between frames");
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let addr = service_addr(&args)?;
    let interval = std::time::Duration::from_millis(args.get_parse("interval-ms", 1000u64)?);
    let samples: u64 = args.get_parse("samples", 0u64)?;
    let plain = args.flag("plain");
    // Counter totals from the previous frame, for the Δ column.
    let mut prev: std::collections::BTreeMap<String, u64> = std::collections::BTreeMap::new();
    let mut frame = 0u64;
    loop {
        let doc = service_roundtrip(&addr, &Request::Metrics)?;
        let m = doc.get("metrics").context("response missing metrics")?;
        let rendered = render_top_frame(m, &mut prev)?;
        if !plain {
            // ANSI clear + home, so the dashboard repaints in place.
            print!("\x1b[2J\x1b[H");
        }
        println!("{rendered}");
        frame += 1;
        if samples != 0 && frame >= samples {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// One `cupso top` frame: header, non-zero counters with per-frame
/// deltas, active histogram series, and a log-binned latency sketch of
/// the round step phase.
fn render_top_frame(
    m: &Json,
    prev: &mut std::collections::BTreeMap<String, u64>,
) -> Result<String> {
    use std::fmt::Write as _;
    let mut out = String::new();
    let uptime = m.get("uptime_s").context("metrics missing uptime_s")?.as_u64("uptime_s")?;
    let enabled = m.get("enabled").context("metrics missing enabled")?.as_bool("enabled")?;
    let trace = m.get("trace").context("metrics missing trace")?;
    let recorded = trace.get("recorded").context("trace.recorded")?.as_u64("recorded")?;
    let _ = writeln!(
        out,
        "cupso top — uptime {uptime}s, telemetry {}, {recorded} trace events, last snapshot {}",
        if enabled { "on" } else { "off" },
        fmt_age(m.num_or_null_field("last_snapshot_age_s")?)
    );
    let mut zeros = 0usize;
    let mut counters = Table::new("Counters", &["Counter", "Total", "Δ"]);
    for (k, v) in obj_fields(m, "counters")? {
        let v = v.as_u64(k)?;
        let delta = v.saturating_sub(prev.insert(k.clone(), v).unwrap_or(v));
        if v == 0 {
            zeros += 1;
            continue;
        }
        counters.row(&[k.clone(), v.to_string(), format!("+{delta}")]);
    }
    if counters.is_empty() {
        let _ = writeln!(out, "(no activity recorded yet)");
    } else {
        out.push_str(&counters.to_markdown());
    }
    if zeros > 0 {
        let _ = writeln!(out, "({zeros} zero counters hidden)");
    }
    let histos = obj_fields(m, "histos")?;
    let mut series = Table::new("Series", &["Series", "Count", "Mean", "Max"]);
    for (k, h) in histos {
        let count = h.get("count").with_context(|| format!("{k}.count"))?.as_u64("count")?;
        if count == 0 {
            continue;
        }
        let mean = h.get("mean").with_context(|| format!("{k}.mean"))?.as_f64("mean")?;
        let max = h.get("max").with_context(|| format!("{k}.max"))?.as_u64("max")?;
        series.row(&[k.clone(), count.to_string(), format!("{mean:.0}"), max.to_string()]);
    }
    if !series.is_empty() {
        out.push_str(&series.to_markdown());
    }
    if let Some((k, h)) = histos.iter().find(|(k, _)| k == "round_step_ns") {
        if let Some(Json::Arr(raw)) = h.get("bins") {
            let bins: Vec<f64> = raw
                .iter()
                .map(|b| b.as_f64("bin"))
                .collect::<Result<_>>()?;
            if bins.iter().any(|&b| b > 0.0) {
                let labels: Vec<String> = (0..bins.len())
                    .map(|b| if b == 0 { "0".to_string() } else { format!("<2^{b}ns") })
                    .collect();
                let plot = AsciiPlot::new(&format!("{k} — events per log2 bin"), 60, 10)
                    .log_y()
                    .x_labels(&labels)
                    .series("events", &bins);
                out.push_str(&plot.render());
            }
        }
    }
    Ok(out)
}

fn cmd_cancel(rest: &[String]) -> Result<()> {
    let spec = Command::new("cancel", "cancel a live job on a running service")
        .opt("socket", "service Unix socket path", None)
        .opt("connect", "service TCP host:port (alternative to --socket)", None)
        .opt("name", "job name (also accepted as a positional argument)", None);
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        println!("usage: cupso cancel --socket <path> <job-name>");
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let addr = service_addr(&args)?;
    let name = args
        .positional
        .first()
        .map(|s| s.as_str())
        .or_else(|| args.get("name"))
        .context("usage: cupso cancel --socket <path> <job-name>")?
        .to_string();
    let doc = service_roundtrip(&addr, &Request::Cancel { name })?;
    let job = doc.get("job").context("missing job")?;
    println!(
        "cancelled {} after {} steps (gbest {})",
        job.str_field("name")?,
        job.get("steps").context("steps")?.as_u64("steps")?,
        fmt_gbest(job.num_or_null_field("gbest")?),
    );
    Ok(())
}

fn cmd_drain(rest: &[String]) -> Result<()> {
    let spec = Command::new("drain", "checkpoint a running service's live jobs and stop it")
        .opt("socket", "service Unix socket path", None)
        .opt("connect", "service TCP host:port (alternative to --socket)", None);
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let addr = service_addr(&args)?;
    let doc = service_roundtrip(&addr, &Request::Drain)?;
    let snapshotted = doc
        .get("snapshotted")
        .context("missing snapshotted")?
        .as_u64("snapshotted")?;
    let finished = doc
        .get("finished")
        .context("missing finished")?
        .as_u64("finished")?;
    match doc.get("dir") {
        Some(dir) => {
            let dir = dir.as_str("dir")?;
            println!(
                "drained {snapshotted} live jobs into {dir} ({finished} already finished) — \
                 continue with `cupso resume {dir}`"
            );
        }
        None => println!("drained: no live jobs to snapshot ({finished} finished)"),
    }
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> Result<()> {
    let spec = Command::new("simulate", "print the Plane-C estimated-GPU tables")
        .opt("table", "3|4|5|all", Some("all"));
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let which = args.get("table").unwrap_or("all");
    if which == "3" || which == "all" {
        let mut t = Table::new(
            "Table 3 (estimated GTX-1080Ti vs paper) — 1-D, 100k iters",
            &["Particles", "CPU", "Reduction", "Unroll", "Queue", "QueueLock", "paper QueueLock"],
        );
        for (n, _, _, _, _, p_ql) in gpusim::paper::TABLE3 {
            let est = |k| gpusim::estimate_seconds(k, n, 1, 100_000);
            t.row(&[
                n.to_string(),
                format!("{:.3}", est(EngineKind::SerialCpu)),
                format!("{:.3}", est(EngineKind::Reduction)),
                format!("{:.3}", est(EngineKind::LoopUnrolling)),
                format!("{:.3}", est(EngineKind::Queue)),
                format!("{:.3}", est(EngineKind::QueueLock)),
                format!("{p_ql:.3}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if which == "4" || which == "all" {
        let mut t = Table::new(
            "Table 4 (estimated) — 1-D speedup, CPU vs Queue Lock",
            &["Particles", "CPU (s)", "QueueLock (s)", "Speedup", "paper"],
        );
        for (n, _, _, p_s) in gpusim::paper::TABLE4 {
            let c = gpusim::estimate_seconds(EngineKind::SerialCpu, n, 1, 100_000);
            let g = gpusim::estimate_seconds(EngineKind::QueueLock, n, 1, 100_000);
            t.row(&[
                n.to_string(),
                format!("{c:.3}"),
                format!("{g:.3}"),
                format!("{:.2}", c / g),
                format!("{p_s:.2}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    if which == "5" || which == "all" {
        let mut t = Table::new(
            "Table 5 (estimated) — 120-D speedup, CPU vs Queue",
            &["Particles", "Iters", "CPU (s)", "Queue (s)", "Speedup", "paper"],
        );
        for ((n, iters), (_, _, _, _, p_s)) in
            gpusim::TABLE5_ROWS.iter().zip(gpusim::paper::TABLE5.iter())
        {
            let c = gpusim::estimate_seconds(EngineKind::SerialCpu, *n, 120, *iters);
            let g = gpusim::estimate_seconds(EngineKind::Queue, *n, 120, *iters);
            t.row(&[
                n.to_string(),
                iters.to_string(),
                format!("{c:.3}"),
                format!("{g:.3}"),
                format!("{:.2}", c / g),
                format!("{p_s:.2}"),
            ]);
        }
        println!("{}", t.to_markdown());
    }
    Ok(())
}

fn cmd_xla(rest: &[String]) -> Result<()> {
    let spec = Command::new("xla", "drive the three-layer AOT stack")
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("variant", "reduction|queue|fused", Some("queue"))
        .opt("particles", "particles per shard (must match an artifact)", Some("1024"))
        .opt("dim", "dimensionality (must match an artifact)", Some("1"))
        .opt("shards", "independent shards", Some("4"))
        .opt("iters", "iterations per shard", Some("500"))
        .opt("seed", "master seed", Some("42"))
        .opt("scheduler", "sync|async", Some("async"));
    if rest.iter().any(|a| a == "--help") {
        println!("{}", spec.usage());
        return Ok(());
    }
    let args = spec.parse(rest)?;
    let rt = XlaRuntime::open(Path::new(args.get("artifacts").unwrap()))?;
    let mut cfg = CoordinatorConfig::new(
        args.get("variant").unwrap(),
        args.get_parse("particles", 1024usize)?,
        args.get_parse("dim", 1usize)?,
        args.get_parse("iters", 500u64)?,
    );
    cfg.shards = args.get_parse("shards", 4usize)?;
    cfg.seed = args.get_parse("seed", 42u64)?;
    let scheduler = args.get("scheduler").unwrap_or("async");

    println!(
        "cupso xla: platform={}, variant={}, {} shards × {} particles × {}d, {} iters, {} scheduler",
        rt.platform(),
        cfg.variant,
        cfg.shards,
        cfg.shard_particles,
        cfg.dim,
        cfg.iters,
        scheduler
    );
    let sw = Stopwatch::start();
    let out = match scheduler {
        "sync" => SyncScheduler::run(&rt, &cfg)?,
        "async" => AsyncScheduler::run(&rt, &cfg)?,
        other => bail!("unknown scheduler {other} (sync|async)"),
    };
    let elapsed = sw.elapsed_s();
    println!("gbest fitness : {:.6}", out.gbest_fit);
    println!("wall time     : {elapsed:.3}s");
    println!(
        "chunk calls   : {} ({} iters/shard), merges: {}",
        out.chunk_calls, out.iters_per_shard, out.merges
    );
    println!(
        "shard fits    : {:?}",
        out.shard_fits.iter().map(|f| format!("{f:.1}")).collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_info(rest: &[String]) -> Result<()> {
    let spec = Command::new("info", "platform + inventory")
        .opt("artifacts", "artifact directory", Some("artifacts"));
    let args = spec.parse(rest)?;
    println!("cupso {} — cuPSO (SAC'22) reproduction", env!("CARGO_PKG_VERSION"));
    println!(
        "cores: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );
    println!("engines: cpu, reduction, unroll, queue, queuelock (+ xla sync/async)");
    println!("fitness: {}", cupso::fitness::ALL_NAMES.join(", "));
    let dir = Path::new(args.get("artifacts").unwrap());
    match XlaRuntime::open(dir) {
        Ok(rt) => {
            println!("artifacts ({}, jax {}):", rt.platform(), rt.manifest().jax_version);
            for m in rt.manifest().iter() {
                println!(
                    "  {:<28} variant={:<9} n={:<6} d={:<3} k={}",
                    m.name, m.variant, m.n, m.dim, m.iters
                );
            }
        }
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    Ok(())
}
