"""L2: the PSO compute graph — K synchronous iterations as a lax.scan.

This is the unit the AOT pipeline lowers to one HLO artifact: the Rust
coordinator calls it in a loop ("chunks"), keeping Python entirely out of
the runtime. The scan carry holds the full swarm state plus the global
best, so there is **no host round trip between iterations** — the
inter-iteration dependency the CUDA version pays kernel launches for is
a carry edge here.

Three aggregation variants mirror the paper's algorithms:

  * ``reduction`` — the baseline: full per-tile argmax every iteration
    (kernels/best_reduce.py) + tiny second-level reduce.
  * ``queue``     — the paper's contribution re-expressed for TPU:
    predicate-then-reduce (kernels/queue_filter.py); the expensive pass
    runs only when something improved.
  * ``fused``     — the queue-lock analog: no aux arrays at all, the
    candidate max updates the gbest carry inline, letting XLA fuse the
    whole iteration into one computation (the "one kernel per iteration"
    structure of Algorithm 3).

All three produce bit-identical trajectories (same argmax tie-breaking);
pytest asserts it.

RNG: counter-based threefry keyed by ``fold_in(key, iter0 + t)`` — the
stateless per-(iteration) streams of cuRAND (§5.4), replayable across
chunks because the Rust side passes the running iteration offset.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import best_reduce as br
from .kernels import pso_step as ps
from .kernels import queue_filter as qf
from .kernels import ref

VARIANTS = ("reduction", "queue", "fused")


def default_params():
    """The paper's §6.1 parameter set on the Cubic domain."""
    return dict(w=1.0, c1=2.0, c2=2.0, min_pos=-100.0, max_pos=100.0, max_v=100.0)


def make_chunk(*, variant="queue", iters=50, params=None, fitness="cubic", tile=None):
    """Build the chunk function ``(state..., key_bits, iter0) -> state...``.

    Signature (all positional, the artifact ABI the Rust runtime uses):

        pos       f64[d, n]     in/out
        vel       f64[d, n]     in/out
        pbest_pos f64[d, n]     in/out
        pbest_fit f64[n]        in/out
        gbest_pos f64[d]        in/out
        gbest_fit f64[]         in/out
        key_bits  u32[2]        in       (threefry key data)
        iter0     i64[]         in       (global iteration offset)

    Returns ``(pos, vel, pbest_pos, pbest_fit, gbest_pos, gbest_fit,
    trace)`` where ``trace`` is ``f64[iters]`` of gbest_fit after each
    iteration (convergence telemetry for the coordinator).
    """
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; expected {VARIANTS}")
    if params is None:
        params = default_params()
    maximize = ref.MAXIMIZE[fitness]

    def chunk(pos, vel, pbp, pbf, gbp, gbf, key_bits, iter0):
        key = jax.random.wrap_key_data(key_bits, impl="threefry2x32")
        d, n = pos.shape
        dtype = pos.dtype

        def body(carry, t):
            pos, vel, pbp, pbf, gbp, gbf = carry
            k = jax.random.fold_in(key, iter0 + t)
            r = jax.random.uniform(k, (2, d, n), dtype)
            pos, vel, pbp, pbf, fit = ps.pso_step(
                pos, vel, pbp, pbf, gbp, r[0], r[1],
                params=params, fitness=fitness, tile=tile,
            )
            if variant == "reduction":
                cand_fit, cand_idx = br.best_reduce(fit, tile=tile, maximize=maximize)
                better = cand_fit > gbf if maximize else cand_fit < gbf
            elif variant == "queue":
                cand_fit, cand_idx, better = qf.queue_filter(
                    fit, gbf, tile=tile, maximize=maximize
                )
            else:  # fused
                cand_idx = jnp.argmax(fit) if maximize else jnp.argmin(fit)
                cand_fit = fit[cand_idx]
                better = cand_fit > gbf if maximize else cand_fit < gbf
            gbf = jnp.where(better, cand_fit, gbf)
            gbp = jnp.where(better, pos[:, cand_idx], gbp)
            return (pos, vel, pbp, pbf, gbp, gbf), gbf

        init = (pos, vel, pbp, pbf, gbp, gbf)
        (pos, vel, pbp, pbf, gbp, gbf), trace = jax.lax.scan(
            body, init, jnp.arange(iters, dtype=jnp.int64)
        )
        return pos, vel, pbp, pbf, gbp, gbf, trace

    chunk.__name__ = f"pso_chunk_{variant}_{fitness}_k{iters}"
    return chunk


def init_state(n, d, *, key, params=None, fitness="cubic", dtype=jnp.float64):
    """Step-1 initialization (uniform positions/velocities, seeded bests).

    Build-time helper for tests and for producing the initial literals the
    Rust runtime feeds the first chunk.
    """
    if params is None:
        params = default_params()
    kp, kv = jax.random.split(key)
    lo, hi = params["min_pos"], params["max_pos"]
    vmax = params["max_v"]
    pos = jax.random.uniform(kp, (d, n), dtype, lo, hi)
    vel = jax.random.uniform(kv, (d, n), dtype, -vmax, vmax)
    fit = ref.FITNESS[fitness](pos)
    maximize = ref.MAXIMIZE[fitness]
    gi = jnp.argmax(fit) if maximize else jnp.argmin(fit)
    return (
        pos,
        vel,
        pos,          # pbest_pos
        fit,          # pbest_fit
        pos[:, gi],   # gbest_pos
        fit[gi],      # gbest_fit
    )


def reference_chunk(*, iters, params=None, fitness="cubic"):
    """Pure-jnp oracle for :func:`make_chunk` (no Pallas, python loop)."""
    if params is None:
        params = default_params()

    def chunk(pos, vel, pbp, pbf, gbp, gbf, key_bits, iter0):
        key = jax.random.wrap_key_data(key_bits, impl="threefry2x32")
        d, n = pos.shape
        state = (pos, vel, pbp, pbf, gbp, gbf)
        trace = []
        for t in range(iters):
            k = jax.random.fold_in(key, iter0 + t)
            r = jax.random.uniform(k, (2, d, n), pos.dtype)
            state = ref.pso_iteration(state, r[0], r[1], params=params, fitness=fitness)
            trace.append(state[5])
        return (*state, jnp.stack(trace))

    return chunk


@functools.lru_cache(maxsize=None)
def _chunk_cache(variant, iters, fitness, n, d):
    """Jitted chunk per static config (used by tests/benches)."""
    fn = make_chunk(variant=variant, iters=iters, fitness=fitness)
    return jax.jit(fn)
