"""L1 Pallas kernel: the fused PSO step (the paper's "1st kernel" body).

One kernel application updates a **particle tile**: velocity (Eq. 1),
position (Eq. 2), clamps, fitness, and the pbest merge — all in VMEM, one
HBM round trip per tile per iteration. The grid dimension over particle
tiles plays the role of the CUDA thread-block grid; ``BlockSpec`` is the
HBM↔VMEM schedule the paper expressed with blocks and coalesced loads
(Figure 2): the particle axis is minor/lane-contiguous.

Hardware adaptation notes (DESIGN.md §Hardware-Adaptation):
  * scalars (w, c1, c2, bounds) are baked at trace time — the constant-
    memory analog (§5.2); XLA constant-folds them into the kernel.
  * the random draws r1/r2 arrive as inputs, produced by counter-based
    threefry in the surrounding jax program (cuRAND analog, §5.4) so the
    kernel itself stays a pure map and lowers into the same HLO module.
  * ``interpret=True`` everywhere: the CPU PJRT client cannot execute
    Mosaic custom-calls; interpret-mode lowers to plain HLO ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Particle-tile width. 8x128-lane friendly; small problems use one tile.
DEFAULT_TILE = 512


def _fitness_tile(p, fitness):
    """Fitness of a [d, tile] position block, reduced over dim axis."""
    return ref.FITNESS[fitness](p)


def _step_kernel(
    pos_ref,
    vel_ref,
    pbp_ref,
    pbf_ref,
    gbp_ref,
    r1_ref,
    r2_ref,
    pos_out,
    vel_out,
    pbp_out,
    pbf_out,
    fit_out,
    *,
    params,
    fitness,
    maximize,
):
    """Kernel body over one [d, tile] block (all refs already in VMEM)."""
    w, c1, c2 = params["w"], params["c1"], params["c2"]
    vmax = params["max_v"]
    lo, hi = params["min_pos"], params["max_pos"]

    pos = pos_ref[...]
    vel = vel_ref[...]
    pbp = pbp_ref[...]
    pbf = pbf_ref[...]
    gbp = gbp_ref[...]  # [d, 1] broadcast against the tile

    v = w * vel + c1 * r1_ref[...] * (pbp - pos) + c2 * r2_ref[...] * (gbp - pos)
    v = jnp.clip(v, -vmax, vmax)
    p = jnp.clip(pos + v, lo, hi)
    fit = _fitness_tile(p, fitness)

    better = fit > pbf if maximize else fit < pbf
    pbf_new = jnp.where(better, fit, pbf)
    pbp_new = jnp.where(better[None, :], p, pbp)

    pos_out[...] = p
    vel_out[...] = v
    pbp_out[...] = pbp_new
    pbf_out[...] = pbf_new
    fit_out[...] = fit


def pso_step(
    pos,
    vel,
    pbest_pos,
    pbest_fit,
    gbest_pos,
    r1,
    r2,
    *,
    params,
    fitness="cubic",
    tile=None,
):
    """Apply the fused step kernel to the whole swarm.

    Shapes: pos/vel/pbest_pos/r1/r2 ``[d, n]``, pbest_fit ``[n]``,
    gbest_pos ``[d]``. Returns the same tuple as :func:`ref.pso_step`.

    ``n`` must be divisible by the tile width (the AOT manifest only emits
    power-of-two swarm sizes; odd sizes fall back to one full-width tile).
    """
    d, n = pos.shape
    dtype = pos.dtype
    if tile is None:
        tile = min(DEFAULT_TILE, n)
    if n % tile != 0:
        tile = n  # single-tile fallback for odd sizes
    grid = (n // tile,)
    maximize = ref.MAXIMIZE[fitness]

    # [d, tile] tiles over the particle axis for the big arrays...
    mat = pl.BlockSpec((d, tile), lambda i: (0, i))
    # ...[tile] for per-particle scalars...
    row = pl.BlockSpec((tile,), lambda i: (i,))
    # ...and the full gbest position replicated to every tile.
    rep = pl.BlockSpec((d, 1), lambda i: (0, 0))

    kernel = functools.partial(
        _step_kernel, params=params, fitness=fitness, maximize=maximize
    )
    out_shape = [
        jax.ShapeDtypeStruct((d, n), dtype),  # pos
        jax.ShapeDtypeStruct((d, n), dtype),  # vel
        jax.ShapeDtypeStruct((d, n), dtype),  # pbest_pos
        jax.ShapeDtypeStruct((n,), dtype),  # pbest_fit
        jax.ShapeDtypeStruct((n,), dtype),  # fit
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[mat, mat, mat, row, rep, mat, mat],
        out_specs=[mat, mat, mat, row, row],
        out_shape=out_shape,
        interpret=True,
    )(pos, vel, pbest_pos, pbest_fit, gbest_pos[:, None], r1, r2)
