"""L1 Pallas kernel: predicate-then-reduce — the *queue algorithm* on TPU.

The CUDA queue (Algorithm 2) exploits that improvements over the global
best are rare (<0.1%): threads conditionally `atomicAdd`-append to a
shared-memory queue, and the scan of that queue is almost always a no-op.

TPUs have no shared-memory atomics, so the insight is re-expressed in
lane-parallel form (DESIGN.md §Hardware-Adaptation):

  1. compute the improvement mask ``fit > gbest_fit`` — one vector
     compare, the analog of Algorithm 2 line 1;
  2. reduce the mask to a scalar ``any`` flag — the analog of the queue
     length ``num``;
  3. only under ``@pl.when(flag)`` run the expensive masked argmax and
     write the real (fit, index) — the analog of thread 0 scanning a
     non-empty queue (lines 10–19). The common case writes only the
     sentinel, skipping the reduction's full data pass.

Both paths write the aux slot (lines 8–9 initialize the aux arrays to
INT_MIN in the paper — same sentinel idea).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _queue_kernel(fit_ref, gbf_ref, aux_fit_ref, aux_idx_ref, *, tile, maximize):
    t = pl.program_id(0)
    fit = fit_ref[...]
    gbf = gbf_ref[0]
    sentinel = -jnp.inf if maximize else jnp.inf

    # Algorithm 2 lines 8-9: initialize the aux slot to the sentinel.
    aux_fit_ref[0] = jnp.asarray(sentinel, fit.dtype)
    aux_idx_ref[0] = jnp.int32(t * tile)

    mask = fit > gbf if maximize else fit < gbf
    improved = jnp.any(mask)  # the queue length `num`

    @pl.when(improved)
    def _scan_queue():
        # Lines 10-19: only entered when the queue is non-empty.
        masked = jnp.where(mask, fit, sentinel)
        local = jnp.argmax(masked) if maximize else jnp.argmin(masked)
        aux_fit_ref[0] = masked[local]
        aux_idx_ref[0] = (t * tile + local).astype(jnp.int32)


def tile_queue_filter(fit, gbest_fit, *, tile=None, maximize=True):
    """Per-tile conditional aggregation.

    ``fit [n]``, ``gbest_fit`` scalar → ``(aux_fit [n/tile],
    aux_idx [n/tile])`` where non-improving tiles carry the sentinel.
    """
    (n,) = fit.shape
    if tile is None:
        tile = min(512, n)
    if n % tile != 0:
        tile = n
    grid = (n // tile,)
    kernel = functools.partial(_queue_kernel, tile=tile, maximize=maximize)
    gbf = jnp.reshape(gbest_fit, (1,)).astype(fit.dtype)
    out_shape = [
        jax.ShapeDtypeStruct((n // tile,), fit.dtype),
        jax.ShapeDtypeStruct((n // tile,), jnp.int32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=out_shape,
        interpret=True,
    )(fit, gbf)


def queue_filter(fit, gbest_fit, *, tile=None, maximize=True):
    """Scalar result matching :func:`ref.queue_filter`:
    ``(best_fit, best_idx, any_improved)``."""
    aux_fit, aux_idx = tile_queue_filter(fit, gbest_fit, tile=tile, maximize=maximize)
    k = jnp.argmax(aux_fit) if maximize else jnp.argmin(aux_fit)
    best_fit = aux_fit[k]
    sentinel = -jnp.inf if maximize else jnp.inf
    improved = best_fit != sentinel
    best_idx = jnp.where(improved, aux_idx[k], 0)
    return best_fit, best_idx, improved
