"""L1 Pallas kernel: per-tile argmax reduction — the *reduction baseline*.

The CUDA baseline tree-reduces every block's fitness array in shared
memory each iteration (Harris-style), then a second kernel reduces the
per-block results. The TPU analog: each grid step reduces its fitness
tile to a (best, index) pair in VMEM and writes it to the aux arrays;
the (tiny) aux array is then reduced by the caller. Unconditional work
every iteration — exactly the cost the queue kernel avoids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reduce_kernel(fit_ref, aux_fit_ref, aux_idx_ref, *, tile, maximize):
    """Reduce one fitness tile to its (best, global index)."""
    t = pl.program_id(0)
    fit = fit_ref[...]
    local = jnp.argmax(fit) if maximize else jnp.argmin(fit)
    aux_fit_ref[0] = fit[local]
    aux_idx_ref[0] = (t * tile + local).astype(jnp.int32)


def tile_best_reduce(fit, *, tile=None, maximize=True):
    """Per-tile reduction: ``fit [n] -> (aux_fit [n/tile], aux_idx [n/tile])``.

    The "1st kernel" half of the reduction approach; the caller (the L2
    model or a second invocation) reduces the aux arrays.
    """
    (n,) = fit.shape
    if tile is None:
        tile = min(512, n)
    if n % tile != 0:
        tile = n
    grid = (n // tile,)
    kernel = functools.partial(_reduce_kernel, tile=tile, maximize=maximize)
    out_shape = [
        jax.ShapeDtypeStruct((n // tile,), fit.dtype),
        jax.ShapeDtypeStruct((n // tile,), jnp.int32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=out_shape,
        interpret=True,
    )(fit)


def best_reduce(fit, *, tile=None, maximize=True):
    """Full two-level reduction to a scalar ``(best_fit, best_idx)``.

    Level 1 is the Pallas tile kernel; level 2 (the "2nd kernel") is a
    plain argmax over the aux arrays — it is tiny (n/tile elements) and
    XLA fuses it with the surrounding update, mirroring the single-block
    second kernel of the paper.
    """
    aux_fit, aux_idx = tile_best_reduce(fit, tile=tile, maximize=maximize)
    k = jnp.argmax(aux_fit) if maximize else jnp.argmin(aux_fit)
    return aux_fit[k], aux_idx[k]
