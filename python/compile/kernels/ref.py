"""Pure-jnp oracles for the Pallas kernels and the full PSO iteration.

Everything here is straight-line jax.numpy with no Pallas, no scan — the
simplest possible statement of the math, used by pytest to validate the
kernels and the scan model. Layout convention everywhere: positions are
``[dim, n]`` (dimension-major, particle-minor — the SoA/coalesced layout
of the paper's Figure 2 adapted to TPU lanes).
"""

from __future__ import annotations

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Fitness functions (the paper's Cubic, Eq. 3, plus alternates).
# ---------------------------------------------------------------------------


def cubic(pos):
    """Eq. 3: sum_d x^3 - 0.8 x^2 - 1000 x + 8000 over dim axis 0."""
    x = pos
    return jnp.sum(((x - 0.8) * x - 1000.0) * x + 8000.0, axis=0)


def sphere(pos):
    """Sum of squares (minimization benchmark)."""
    return jnp.sum(pos * pos, axis=0)


def rastrigin(pos):
    """10 d + sum (x^2 - 10 cos 2 pi x)."""
    d = pos.shape[0]
    return 10.0 * d + jnp.sum(
        pos * pos - 10.0 * jnp.cos(2.0 * jnp.pi * pos), axis=0
    )


FITNESS = {"cubic": cubic, "sphere": sphere, "rastrigin": rastrigin}

# Whether larger is better, per function (the paper maximizes Cubic).
MAXIMIZE = {"cubic": True, "sphere": False, "rastrigin": False}


# ---------------------------------------------------------------------------
# Step kernel oracle.
# ---------------------------------------------------------------------------


def pso_step(pos, vel, pbest_pos, pbest_fit, gbest_pos, r1, r2, *, params, fitness="cubic"):
    """One synchronous PSO update for the whole swarm.

    Args:
        pos, vel, pbest_pos: ``[d, n]``.
        pbest_fit: ``[n]``.
        gbest_pos: ``[d]`` (frozen for the iteration).
        r1, r2: ``[d, n]`` uniforms in [0, 1).
        params: dict with w, c1, c2, min_pos, max_pos, max_v.
        fitness: fitness key in ``FITNESS``.

    Returns:
        (pos', vel', pbest_pos', pbest_fit', fit') with fit' ``[n]``.
    """
    w, c1, c2 = params["w"], params["c1"], params["c2"]
    vmax = params["max_v"]
    lo, hi = params["min_pos"], params["max_pos"]
    maximize = MAXIMIZE[fitness]

    v = w * vel + c1 * r1 * (pbest_pos - pos) + c2 * r2 * (gbest_pos[:, None] - pos)
    v = jnp.clip(v, -vmax, vmax)
    p = jnp.clip(pos + v, lo, hi)
    fit = FITNESS[fitness](p)
    better = fit > pbest_fit if maximize else fit < pbest_fit
    new_pbest_fit = jnp.where(better, fit, pbest_fit)
    new_pbest_pos = jnp.where(better[None, :], p, pbest_pos)
    return p, v, new_pbest_pos, new_pbest_fit, fit


# ---------------------------------------------------------------------------
# Aggregation oracles.
# ---------------------------------------------------------------------------


def best_reduce(fit, *, maximize=True):
    """Full argmax/argmin reduction: returns (best_fit, best_idx)."""
    idx = jnp.argmax(fit) if maximize else jnp.argmin(fit)
    return fit[idx], idx


def queue_filter(fit, gbest_fit, *, maximize=True):
    """The queue-algorithm semantics: the best *improving* candidate.

    Returns (best_fit, best_idx, any_improved). When nothing improves,
    best_fit is the sentinel (-inf for maximize) and best_idx is 0 —
    matching the kernel's cheap no-improvement path.
    """
    mask = fit > gbest_fit if maximize else fit < gbest_fit
    sentinel = -jnp.inf if maximize else jnp.inf
    masked = jnp.where(mask, fit, sentinel)
    any_improved = jnp.any(mask)
    best_fit, best_idx = best_reduce(masked, maximize=maximize)
    best_fit = jnp.where(any_improved, best_fit, sentinel)
    best_idx = jnp.where(any_improved, best_idx, 0)
    return best_fit, best_idx, any_improved


# ---------------------------------------------------------------------------
# Full-iteration oracle (synchronous PPSO semantics).
# ---------------------------------------------------------------------------


def pso_iteration(state, r1, r2, *, params, fitness="cubic"):
    """One full synchronous iteration: step + gbest update.

    ``state`` is (pos, vel, pbest_pos, pbest_fit, gbest_pos, gbest_fit).
    """
    pos, vel, pbp, pbf, gbp, gbf = state
    maximize = MAXIMIZE[fitness]
    pos, vel, pbp, pbf, fit = pso_step(
        pos, vel, pbp, pbf, gbp, r1, r2, params=params, fitness=fitness
    )
    cand_fit, cand_idx = best_reduce(fit, maximize=maximize)
    better = cand_fit > gbf if maximize else cand_fit < gbf
    gbf = jnp.where(better, cand_fit, gbf)
    gbp = jnp.where(better, pos[:, cand_idx], gbp)
    return (pos, vel, pbp, pbf, gbp, gbf)
