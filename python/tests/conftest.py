"""Shared pytest fixtures. x64 must be enabled before any jax import in
the test modules (f64 end-to-end, matching the paper's double precision).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402

from compile import model  # noqa: E402


@pytest.fixture(scope="session")
def paper_params():
    return model.default_params()


def make_swarm(n, d, seed=0, dtype=jnp.float64):
    """Random-but-deterministic swarm state for tests."""
    key = jax.random.PRNGKey(seed)
    return model.init_state(n, d, key=key, dtype=dtype)
