"""L2 correctness: the scan-chunk model vs the pure python-loop oracle,
cross-variant equivalence, convergence behaviour, and chunk chaining
(the ABI property the Rust coordinator depends on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

from .conftest import make_swarm

KEY_BITS = jax.random.key_data(jax.random.PRNGKey(2022))


def run_chunk(variant, state, iters, iter0=0):
    fn = jax.jit(model.make_chunk(variant=variant, iters=iters))
    return fn(*state, KEY_BITS, jnp.int64(iter0))


class TestVariantEquivalence:
    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([64, 256, 1024]),
        d=st.sampled_from([1, 3, 120]),
        seed=st.integers(0, 1000),
    )
    def test_all_variants_identical(self, n, d, seed):
        state = make_swarm(n, d, seed)
        outs = [run_chunk(v, state, 8) for v in model.VARIANTS]
        for v, o in zip(model.VARIANTS[1:], outs[1:]):
            for a, b in zip(outs[0], o):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b), err_msg=f"{v} diverged (n={n} d={d})"
                )

    def test_variants_match_python_loop_oracle(self):
        state = make_swarm(128, 2, 1)
        oracle = model.reference_chunk(iters=12)(*state, KEY_BITS, jnp.int64(0))
        for v in model.VARIANTS:
            out = run_chunk(v, state, 12)
            for a, b, name in zip(
                out, oracle, ["pos", "vel", "pbp", "pbf", "gbp", "gbf", "trace"]
            ):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-12, err_msg=f"{v}:{name}"
                )


class TestConvergence:
    def test_gbest_trace_is_monotone(self):
        state = make_swarm(256, 120, 3)
        out = run_chunk("queue", state, 30)
        trace = np.asarray(out[6])
        assert np.all(np.diff(trace) >= 0), "gbest worsened within a chunk"

    def test_solves_cubic_1d(self):
        state = make_swarm(512, 1, 4)
        out = run_chunk("fused", state, 60)
        assert float(out[5]) > 899_000.0  # optimum 900k at x=100

    def test_positions_stay_in_bounds(self):
        state = make_swarm(128, 5, 9)
        out = run_chunk("queue", state, 25)
        pos = np.asarray(out[0])
        assert pos.max() <= 100.0 + 1e-9 and pos.min() >= -100.0 - 1e-9
        vel = np.asarray(out[1])
        assert np.abs(vel).max() <= 100.0 + 1e-9


class TestChunkChaining:
    """Two chunks of K must equal one chunk of 2K when iter0 is threaded —
    the exact contract the Rust coordinator relies on."""

    def test_chaining_equals_single_long_chunk(self):
        state = make_swarm(256, 3, 7)
        single = run_chunk("queue", state, 20)
        half1 = run_chunk("queue", state, 10, iter0=0)
        half2 = run_chunk("queue", tuple(half1[:6]), 10, iter0=10)
        for a, b, name in zip(
            half2[:6], single[:6], ["pos", "vel", "pbp", "pbf", "gbp", "gbf"]
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
        # Traces concatenate.
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(half1[6]), np.asarray(half2[6])]),
            np.asarray(single[6]),
        )

    def test_different_iter0_gives_different_randomness(self):
        # One iteration only: longer 1-D/2-D cubic runs clamp every
        # particle onto the domain corner, where different random draws
        # produce identical (saturated) positions.
        state = make_swarm(64, 2, 5)
        a = run_chunk("queue", state, 1, iter0=0)
        b = run_chunk("queue", state, 1, iter0=1000)
        assert not np.array_equal(np.asarray(a[1]), np.asarray(b[1])), "velocities"


class TestInitState:
    def test_shapes_and_bounds(self):
        state = model.init_state(128, 7, key=jax.random.PRNGKey(0))
        pos, vel, pbp, pbf, gbp, gbf = state
        assert pos.shape == (7, 128) and pbf.shape == (128,) and gbp.shape == (7,)
        assert float(jnp.max(pos)) <= 100.0 and float(jnp.min(pos)) >= -100.0
        np.testing.assert_array_equal(np.asarray(pos), np.asarray(pbp))

    def test_gbest_is_swarm_argmax(self):
        state = model.init_state(64, 2, key=jax.random.PRNGKey(1))
        _, _, _, pbf, _, gbf = state
        assert float(gbf) == float(jnp.max(pbf))

    def test_rejects_unknown_variant(self):
        with pytest.raises(ValueError):
            model.make_chunk(variant="warp")
