"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps the shape/dtype/seed space — the CORE correctness
signal for the kernel layer (kernels run under interpret=True, so these
semantics are exactly what the AOT artifacts embed).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import best_reduce as br
from compile.kernels import pso_step as ps
from compile.kernels import queue_filter as qf
from compile.kernels import ref

from .conftest import make_swarm

# Tolerances per dtype: interpret-mode Pallas and jnp share the same
# scalar semantics, so f64 agrees to near-ulp. f32 needs an absolute
# floor: Cubic spans ±1e6 and crosses zero, so a 1-ulp position
# difference (XLA may fuse mul-adds differently) moves the fitness by
# O(1) absolute — meaningless relative to the value range, fatal to a
# pure rtol check near the zeros.
# (f64 dim-sums may associate differently between the tiled kernel and
# the oracle: a few ulps at 1e6 scale ⇒ atol ~1e-8.)
TOL = {jnp.float64: dict(rtol=1e-9, atol=1e-7), jnp.float32: dict(rtol=1e-4, atol=2.0)}

DIMS = st.sampled_from([1, 2, 3, 7, 120])
SIZES = st.sampled_from([64, 128, 256, 512, 1024])
TILES = st.sampled_from([None, 64, 128, 512])
DTYPES = st.sampled_from([jnp.float64, jnp.float32])


def _rand_inputs(n, d, seed, dtype):
    params = model.default_params()
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    pos = jax.random.uniform(ks[0], (d, n), dtype, -100.0, 100.0)
    vel = jax.random.uniform(ks[1], (d, n), dtype, -100.0, 100.0)
    pbp = jax.random.uniform(ks[2], (d, n), dtype, -100.0, 100.0)
    pbf = ref.cubic(pbp)
    gbp = pos[:, 0]
    r1 = jax.random.uniform(ks[3], (d, n), dtype)
    r2 = jax.random.uniform(ks[4], (d, n), dtype)
    return params, pos, vel, pbp, pbf, gbp, r1, r2


class TestStepKernel:
    @settings(max_examples=25, deadline=None)
    @given(n=SIZES, d=DIMS, seed=st.integers(0, 2**31 - 1), tile=TILES, dtype=DTYPES)
    def test_matches_ref(self, n, d, seed, tile, dtype):
        params, pos, vel, pbp, pbf, gbp, r1, r2 = _rand_inputs(n, d, seed, dtype)
        want = ref.pso_step(pos, vel, pbp, pbf, gbp, r1, r2, params=params)
        got = ps.pso_step(pos, vel, pbp, pbf, gbp, r1, r2, params=params, tile=tile)
        for w, g, name in zip(want, got, ["pos", "vel", "pbp", "pbf", "fit"]):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), err_msg=f"{name} n={n} d={d}", **TOL[dtype]
            )

    def test_odd_size_falls_back_to_single_tile(self):
        # 300 is not divisible by the default tile; must still be correct.
        params, pos, vel, pbp, pbf, gbp, r1, r2 = _rand_inputs(300, 2, 3, jnp.float64)
        want = ref.pso_step(pos, vel, pbp, pbf, gbp, r1, r2, params=params)
        got = ps.pso_step(pos, vel, pbp, pbf, gbp, r1, r2, params=params)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-12)

    def test_clamps_are_enforced(self):
        params, pos, vel, pbp, pbf, gbp, r1, r2 = _rand_inputs(128, 3, 1, jnp.float64)
        vel = vel * 1e6  # force the clamp
        got = ps.pso_step(pos, vel, pbp, pbf, gbp, r1, r2, params=params)
        assert float(jnp.max(jnp.abs(got[1]))) <= params["max_v"] + 1e-9
        assert float(jnp.max(got[0])) <= params["max_pos"] + 1e-9
        assert float(jnp.min(got[0])) >= params["min_pos"] - 1e-9

    def test_sphere_fitness_variant(self):
        params, pos, vel, pbp, pbf, gbp, r1, r2 = _rand_inputs(128, 4, 5, jnp.float64)
        pbf = ref.sphere(pbp)
        want = ref.pso_step(pos, vel, pbp, pbf, gbp, r1, r2, params=params, fitness="sphere")
        got = ps.pso_step(
            pos, vel, pbp, pbf, gbp, r1, r2, params=params, fitness="sphere"
        )
        for w, g in zip(want, got):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-12)


class TestBestReduce:
    @settings(max_examples=25, deadline=None)
    @given(n=SIZES, seed=st.integers(0, 2**31 - 1), tile=TILES)
    def test_matches_argmax(self, n, seed, tile):
        fit = jax.random.uniform(jax.random.PRNGKey(seed), (n,), jnp.float64, -1e6, 1e6)
        wf, wi = ref.best_reduce(fit)
        gf, gi = br.best_reduce(fit, tile=tile)
        assert float(wf) == float(gf)
        assert int(wi) == int(gi)

    def test_minimize_sense(self):
        fit = jnp.asarray([5.0, -2.0, 7.0, -2.0])
        gf, gi = br.best_reduce(fit, maximize=False)
        assert float(gf) == -2.0
        assert int(gi) == 1  # first minimum wins

    def test_duplicate_max_takes_first_index(self):
        fit = jnp.asarray([1.0, 9.0, 9.0, 3.0] * 64)
        gf, gi = br.best_reduce(fit, tile=64)
        assert float(gf) == 9.0
        assert int(gi) == 1

    def test_tile_level_outputs(self):
        fit = jnp.arange(256, dtype=jnp.float64)
        aux_fit, aux_idx = br.tile_best_reduce(fit, tile=64)
        assert aux_fit.shape == (4,)
        np.testing.assert_allclose(np.asarray(aux_fit), [63.0, 127.0, 191.0, 255.0])
        np.testing.assert_array_equal(np.asarray(aux_idx), [63, 127, 191, 255])


class TestQueueFilter:
    @settings(max_examples=25, deadline=None)
    @given(
        n=SIZES,
        seed=st.integers(0, 2**31 - 1),
        tile=TILES,
        quantile=st.sampled_from([0.0, 0.5, 0.9, 1.0]),
    )
    def test_matches_ref_across_thresholds(self, n, seed, tile, quantile):
        fit = jax.random.uniform(jax.random.PRNGKey(seed), (n,), jnp.float64, -1e6, 1e6)
        gbf = float(jnp.quantile(fit, quantile))
        wf, wi, wany = ref.queue_filter(fit, gbf)
        gf, gi, gany = qf.queue_filter(fit, gbf, tile=tile)
        assert bool(wany) == bool(gany)
        assert float(wf) == float(gf)
        if bool(wany):
            assert int(wi) == int(gi)

    def test_no_improvement_is_cheap_sentinel(self):
        fit = jnp.zeros(256, jnp.float64)
        gf, gi, gany = qf.queue_filter(fit, 1.0, tile=64)
        assert not bool(gany)
        assert float(gf) == -np.inf

    def test_single_improver_found_in_any_tile(self):
        for hot in [0, 63, 64, 200, 255]:
            fit = jnp.zeros(256, jnp.float64).at[hot].set(5.0)
            gf, gi, gany = qf.queue_filter(fit, 1.0, tile=64)
            assert bool(gany)
            assert int(gi) == hot
            assert float(gf) == 5.0

    def test_minimize_sense(self):
        fit = jnp.asarray([5.0, 1.0, 3.0, 0.5] * 32)
        gf, gi, gany = qf.queue_filter(fit, 0.75, tile=32, maximize=False)
        assert bool(gany)
        assert float(gf) == 0.5
        assert int(gi) == 3
