"""AOT pipeline: lowered HLO text is well-formed, the manifest describes
it accurately, and lowering is deterministic."""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    configs = [("queue", 128, 1, 5), ("fused", 128, 2, 5)]
    names = aot.build(str(out), configs, verbose=False)
    return out, names, configs


class TestBuild:
    def test_writes_all_files(self, built):
        out, names, configs = built
        assert len(names) == len(configs)
        for name in names:
            assert (out / f"{name}.hlo.txt").exists()
        assert (out / "manifest.toml").exists()

    def test_hlo_text_is_parseable_module(self, built):
        out, names, _ = built
        for name in names:
            text = (out / f"{name}.hlo.txt").read_text()
            assert text.startswith("HloModule"), f"{name} is not HLO text"
            assert "ENTRY" in text
            # The ABI: 8 inputs, 7-tuple output, f64 state.
            assert "u32[2]" in text
            assert "f64[" in text

    def test_manifest_describes_artifacts(self, built):
        out, names, configs = built
        mf = (out / "manifest.toml").read_text()
        for name, (variant, n, d, k) in zip(names, configs):
            assert f"[artifact.{name}]" in mf
            assert f'variant = "{variant}"' in mf
            assert f"n = {n}" in mf
            assert f"dim = {d}" in mf
            assert f"iters = {k}" in mf
        assert "outputs = 7" in mf

    def test_manifest_hashes_match_files(self, built):
        import hashlib

        out, names, _ = built
        mf = (out / "manifest.toml").read_text()
        for name in names:
            text = (out / f"{name}.hlo.txt").read_text()
            sha = hashlib.sha256(text.encode()).hexdigest()
            assert sha in mf, f"stale hash for {name}"


class TestLowering:
    def test_deterministic(self):
        a = aot.lower_chunk("queue", 64, 1, 3)
        b = aot.lower_chunk("queue", 64, 1, 3)
        assert a == b

    def test_scalars_are_baked(self):
        # w, c1, c2 are compile-time constants: no runtime parameter should
        # carry them (8 params exactly: 6 state + key + iter0).
        text = aot.lower_chunk("fused", 64, 1, 3)
        entry = text[text.index("ENTRY"):]
        n_params = entry.count("parameter(")
        assert n_params == 8, f"expected 8 entry params, found {n_params}"

    def test_artifact_name_round_trip(self):
        assert aot.artifact_name("queue", 1024, 120, 25) == "pso_queue_n1024_d120_k25"

    def test_variant_structure_differs(self):
        # The three variants must lower to genuinely different programs
        # (otherwise the xla_runtime bench compares nothing).
        texts = {v: aot.lower_chunk(v, 128, 1, 3) for v in model.VARIANTS}
        assert len(set(texts.values())) == 3
