#!/usr/bin/env bash
# Promote a measured bench JSON document (downloaded from the CI
# `bench-json` artifact, or produced locally with CUPSO_BENCH_JSON) to a
# committed baseline at the repo root:
#
#   bench_promote.sh <measured.json> [dest.json]
#
# Guardrails — a baseline must carry real provenance, never a guess:
#   * the document must name its bench and its git_rev;
#   * the git_rev must be an actual commit in this repository AND an
#     ancestor of HEAD (numbers from a rebase orphan or another clone
#     are rejected);
#   * placeholder revisions ("unknown", "baseline-estimate") are
#     rejected outright.
#
# On success the document is copied to the destination (default
# BENCH_<bench>.json at the repo root), a delta report against any prior
# baseline is printed via bench_compare.sh, and the copy is left for a
# reviewed `git commit`. See EXPERIMENTS.md §Bench baselines.
set -euo pipefail

if [ "$#" -lt 1 ] || [ "$#" -gt 2 ]; then
  echo "usage: $0 <measured.json> [dest.json]" >&2
  exit 2
fi
src="$1"
if [ ! -f "$src" ]; then
  echo "bench_promote: no such file: $src" >&2
  exit 2
fi
root="$(git rev-parse --show-toplevel)"

field() {
  sed -n "s/^  \"$1\": \"\(.*\)\",*$/\1/p" "$src" | head -n 1
}
bench="$(field bench)"
rev="$(field git_rev)"
if [ -z "$bench" ]; then
  echo "bench_promote: $src has no \"bench\" field — not a benchkit document" >&2
  exit 1
fi
case "$rev" in
  ""|unknown|baseline-estimate)
    echo "bench_promote: $src has placeholder git_rev \"$rev\" — refusing:" >&2
    echo "a committed baseline needs real provenance (re-run the bench in a git checkout)" >&2
    exit 1
    ;;
esac
if ! git -C "$root" cat-file -e "$rev^{commit}" 2>/dev/null; then
  echo "bench_promote: git_rev $rev is not a commit in this repository" >&2
  exit 1
fi
if ! git -C "$root" merge-base --is-ancestor "$rev" HEAD; then
  echo "bench_promote: git_rev $rev is not an ancestor of HEAD — these numbers" >&2
  echo "were taken on a branch this history does not contain" >&2
  exit 1
fi

dest="${2:-$root/BENCH_$bench.json}"
if [ -f "$dest" ]; then
  echo "delta vs the current baseline:"
  bash "$root/scripts/bench_compare.sh" "$dest" "$src" || true
fi
cp "$src" "$dest"
echo "promoted $src -> $dest (bench \"$bench\", measured at $rev)"
echo "review the delta above, then commit the new baseline."
