#!/usr/bin/env bash
# Diff two BENCH_*.json documents produced by the benchkit JSON writer
# (rust/src/benchkit/json.rs) and report per-record metric deltas.
#
#   bench_compare.sh [--strict] baseline.json candidate.json
#
# Records are matched by their identity fields (mode, engine, streams,
# batch_steps, jobs, particles, paper_iters, phase, clients, watchers,
# every); the compared metrics are the timing and ratio fields (*_ns,
# *_us, *_ms, *_s, speedup*, *_overhead). Time metrics that grew by
# more than BENCH_COMPARE_MAX_REGRESSION percent (default 25) are
# flagged; with --strict any flagged metric makes the script exit 1.
# Ratio and rate metrics (speedup, *_vs_*, *_per_s) are reported but
# never flagged — higher is better there. See EXPERIMENTS.md §Bench
# baselines for the thresholds and the promotion workflow.
#
# The writer emits one key per line at fixed indentation, so this parser
# is plain awk — no jq dependency.
set -euo pipefail

strict=0
threshold="${BENCH_COMPARE_MAX_REGRESSION:-25}"
args=()
for a in "$@"; do
  case "$a" in
    --strict) strict=1 ;;
    -h|--help)
      echo "usage: $0 [--strict] baseline.json candidate.json" >&2
      exit 0
      ;;
    *) args+=("$a") ;;
  esac
done
if [ "${#args[@]}" -ne 2 ]; then
  echo "usage: $0 [--strict] baseline.json candidate.json" >&2
  exit 2
fi
base="${args[0]}"
cand="${args[1]}"
for f in "$base" "$cand"; do
  if [ ! -f "$f" ]; then
    echo "bench_compare: no such file: $f" >&2
    exit 2
  fi
done

awk -v strict="$strict" -v threshold="$threshold" '
  function trim(s) {
    gsub(/^[ \t]+/, "", s)
    gsub(/[ \t,]+$/, "", s)
    return s
  }
  FNR == 1 { doc++ }
  /^  "bench":/ { split($0, p, "\""); bench[doc] = p[4] }
  /^  "scale":/ { split($0, p, "\""); scale[doc] = p[4] }
  /^  "git_rev":/ { split($0, p, "\""); rev[doc] = p[4] }
  /^    \{/ { delete cur }
  /^      "/ {
    line = trim($0)
    sep = index(line, "\": ")
    key = substr(line, 2, sep - 2)
    val = substr(line, sep + 3)
    gsub(/^"|"$/, "", val)
    cur[key] = val
  }
  /^    \}/ {
    id = ""
    nid = split("mode engine streams batch_steps jobs particles paper_iters phase clients watchers every", idk, " ")
    for (i = 1; i <= nid; i++)
      if (idk[i] in cur) id = id (id == "" ? "" : " ") idk[i] "=" cur[idk[i]]
    for (k in cur) {
      if (k !~ /_ns$|_us$|_ms$|_s$|speedup|_overhead$/) continue
      if (cur[k] !~ /^-?[0-9]/) continue # null: non-finite in the writer
      v[doc, id, k] = cur[k]
      if (doc == 2 && !((id SUBSEP k) in seen)) {
        seen[id SUBSEP k] = 1
        list[++m] = id SUBSEP k
      }
    }
  }
  END {
    printf "bench_compare: %s @ %s  ->  %s @ %s\n", \
      bench[1], rev[1], bench[2], rev[2]
    if (bench[1] != bench[2])
      printf "WARNING: comparing different benches (%s vs %s)\n", bench[1], bench[2]
    if (scale[1] != scale[2])
      printf "WARNING: different scales (%s vs %s) — deltas are not comparable\n", \
        scale[1], scale[2]
    printf "%-52s %-28s %14s %14s %9s\n", "record", "metric", "baseline", "candidate", "delta"
    bad = 0
    for (i = 1; i <= m; i++) {
      split(list[i], a, SUBSEP)
      id = a[1]; k = a[2]
      c = v[2, id, k] + 0
      if ((1, id, k) in v) {
        b = v[1, id, k] + 0
        delta = (b != 0) ? (c - b) / b * 100 : 0
        flag = ""
        if (k ~ /_ns$|_us$|_ms$|_s$/ && k !~ /_per_s$/ && delta > threshold + 0) { flag = "  << regression"; bad++ }
        printf "%-52s %-28s %14.3f %14.3f %+8.1f%%%s\n", id, k, b, c, delta, flag
      } else {
        printf "%-52s %-28s %14s %14.3f    (new)\n", id, k, "-", c
      }
    }
    if (bad > 0) {
      printf "%d time metric(s) regressed beyond %s%%\n", bad, threshold
      if (strict) exit 1
    }
  }
' "$base" "$cand"
