#!/usr/bin/env bash
# Service smoke: start `cupso serve` on a temp Unix socket AND a TCP
# port, submit one job per transport, poll status until both finish,
# then drain over TCP — failing loudly on any protocol error or hang.
# CI wraps this in `timeout` so a wedged daemon fails the job instead
# of stalling it.
set -euo pipefail

BIN=${CUPSO_BIN:-target/release/cupso}
WORK=$(mktemp -d)
SOCK="$WORK/cupso.sock"
SNAP="$WORK/drain"
# Ephemeral-ish TCP port; RANDOM keeps parallel runs from colliding.
PORT=$(( 20000 + RANDOM % 20000 ))
ADDR="127.0.0.1:$PORT"

cleanup() {
    if [[ -n "${SERVE_PID:-}" ]] && kill -0 "$SERVE_PID" 2>/dev/null; then
        kill "$SERVE_PID" 2>/dev/null || true
        wait "$SERVE_PID" 2>/dev/null || true
    fi
    rm -rf "$WORK"
}
trap cleanup EXIT

echo "== starting cupso serve on $SOCK + tcp $ADDR"
TRACE="$WORK/trace.log"
"$BIN" serve --socket "$SOCK" --listen "$ADDR" --max-conns 64 \
    --checkpoint-dir "$SNAP" --trace-dump "$TRACE" &
SERVE_PID=$!

# Wait for the daemon to answer the protocol (not just bind the socket).
for _ in $(seq 1 100); do
    if "$BIN" status --socket "$SOCK" >/dev/null 2>&1; then
        break
    fi
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "serve died before becoming reachable" >&2
        exit 1
    fi
    sleep 0.1
done
"$BIN" status --socket "$SOCK" >/dev/null

echo "== TCP leg: status over --connect"
"$BIN" status --connect "$ADDR" >/dev/null

echo "== submitting one sphere job over the Unix socket"
"$BIN" submit --socket "$SOCK" --name smoke --fitness sphere --dim 3 \
    --particles 64 --iters 400 --engine queue --seed 7 | tee "$WORK/submit.out"
grep -q "submitted smoke" "$WORK/submit.out"

echo "== submitting one cubic job over TCP with a tenant label"
"$BIN" submit --connect "$ADDR" --name smoke-tcp --fitness cubic \
    --particles 64 --iters 400 --engine queue --seed 8 --tenant demo \
    | tee "$WORK/submit_tcp.out"
grep -q "submitted smoke-tcp" "$WORK/submit_tcp.out"

echo "== metrics leg: status --metrics (both transports) + one cupso top frame"
"$BIN" status --socket "$SOCK" --metrics >"$WORK/metrics.out"
grep -q "# TYPE cupso_rounds_total counter" "$WORK/metrics.out"
grep -Eq "^cupso_jobs_admitted_total [1-9]" "$WORK/metrics.out"
grep -q "cupso_uptime_seconds" "$WORK/metrics.out"
"$BIN" status --connect "$ADDR" --metrics | grep -q "cupso_rounds_total"
"$BIN" top --socket "$SOCK" --samples 1 --plain >"$WORK/top.out"
grep -q "jobs_admitted_total" "$WORK/top.out"

echo "== polling status (over TCP) until both jobs finish"
DONE=0
for _ in $(seq 1 200); do
    "$BIN" status --connect "$ADDR" >"$WORK/status.out"
    if grep -q "0 live, 2 finished" "$WORK/status.out"; then
        DONE=1
        break
    fi
    sleep 0.1
done
if [[ "$DONE" != 1 ]]; then
    echo "jobs never finished; last status:" >&2
    cat "$WORK/status.out" >&2
    exit 1
fi
grep -q "smoke" "$WORK/status.out"
grep -q "smoke-tcp" "$WORK/status.out"
grep -q "exhausted" "$WORK/status.out"

echo "== draining over TCP"
"$BIN" drain --connect "$ADDR" | tee "$WORK/drain.out"
grep -q "no live jobs" "$WORK/drain.out"

echo "== waiting for the daemon to exit"
wait "$SERVE_PID"
SERVE_PID=""

echo "== trace ring dumped to --trace-dump on drain"
grep -q "== cupso trace ring (drain):" "$TRACE"
grep -q "event=admit" "$TRACE"
grep -q "event=drain" "$TRACE"
grep -q "== end trace ring ==" "$TRACE"

# ---------------------------------------------------------------------
# Crash leg (ISSUE 9): kill -9 a daemon mid-run, restart it on the same
# --checkpoint-dir with no config, and the adopted job still finishes.
# ---------------------------------------------------------------------
CRASH="$WORK/crash"
SOCK2="$WORK/cupso2.sock"
SOCK3="$WORK/cupso3.sock"

echo "== crash leg: serve with periodic snapshots every 5 rounds"
"$BIN" serve --socket "$SOCK2" --checkpoint-dir "$CRASH" --checkpoint-every 5 \
    >"$WORK/serve2.out" 2>&1 &
SERVE_PID=$!
for _ in $(seq 1 100); do
    if "$BIN" status --socket "$SOCK2" >/dev/null 2>&1; then
        break
    fi
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "crash-leg serve died before becoming reachable" >&2
        cat "$WORK/serve2.out" >&2
        exit 1
    fi
    sleep 0.1
done

echo "== submitting a long job, waiting for the first committed snapshot"
"$BIN" submit --socket "$SOCK2" --name phoenix --fitness sphere --dim 2 \
    --particles 64 --iters 1_000_000 --engine queue --seed 9 >/dev/null
FOUND=0
for _ in $(seq 1 100); do
    if [[ -f "$CRASH/manifest.toml" ]]; then
        FOUND=1
        break
    fi
    sleep 0.05
done
if [[ "$FOUND" != 1 ]]; then
    echo "no snapshot committed before the kill" >&2
    exit 1
fi

echo "== kill -9 (no shutdown code runs)"
kill -9 "$SERVE_PID"
wait "$SERVE_PID" 2>/dev/null || true
SERVE_PID=""

echo "== warm restart on the same --checkpoint-dir (no --config)"
"$BIN" serve --socket "$SOCK3" --checkpoint-dir "$CRASH" \
    >"$WORK/serve3.out" 2>&1 &
SERVE_PID=$!
for _ in $(seq 1 100); do
    if "$BIN" status --socket "$SOCK3" >/dev/null 2>&1; then
        break
    fi
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "restarted serve died before becoming reachable" >&2
        cat "$WORK/serve3.out" >&2
        exit 1
    fi
    sleep 0.1
done
grep -q "warm restart" "$WORK/serve3.out"

echo "== polling until the adopted job finishes"
DONE=0
for _ in $(seq 1 600); do
    "$BIN" status --socket "$SOCK3" >"$WORK/status3.out"
    if grep -q "0 live, 1 finished" "$WORK/status3.out"; then
        DONE=1
        break
    fi
    sleep 0.1
done
if [[ "$DONE" != 1 ]]; then
    echo "adopted job never finished; last status:" >&2
    cat "$WORK/status3.out" >&2
    exit 1
fi
grep -q "phoenix" "$WORK/status3.out"

echo "== draining the recovered daemon"
"$BIN" drain --socket "$SOCK3" >/dev/null
wait "$SERVE_PID"
SERVE_PID=""

echo "service smoke OK (crash leg included)"
