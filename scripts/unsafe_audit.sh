#!/usr/bin/env bash
# Audit every `unsafe` site in rust/src for an adjacent justification.
#
# Policy (enforced in the CI lint job):
#   * an audit site is a non-comment, non-attribute code line containing
#     the `unsafe` keyword as a whole word (`grep -w`) — identifiers and
#     attribute arguments such as the crate-root
#     `#![deny(unsafe_op_in_unsafe_fn)]` lint are not sites, and neither
#     is comment prose mentioning unsafety;
#   * every site must have a `// SAFETY:` comment (or a `/// # Safety`
#     contract doc for `unsafe fn` declarations) within the WINDOW lines
#     above it, on it, or — for `unsafe fn` with the doc contract —
#     anywhere in its doc block;
#   * `#![deny(unsafe_op_in_unsafe_fn)]` (lib.rs) makes every unsafe
#     *operation* inside an `unsafe fn` need its own block, so this
#     check covers operations, not just function boundaries.
#
# Output: a per-file inventory of unsafe sites, then a non-zero exit if
# any site lacks a justification.
set -euo pipefail

cd "$(dirname "$0")/.."
SRC=rust/src
WINDOW=6

# Audit sites: the `unsafe` keyword as a whole word (underscored
# identifiers don't match `-w`), skipping comment-only lines and
# attribute lines (`#[...]` / `#![...]`).
SITES=$(
    grep -rnw --include='*.rs' 'unsafe' "$SRC" \
        | grep -vE '^[^:]+:[0-9]+:[[:space:]]*//' \
        | grep -vE '^[^:]+:[0-9]+:[[:space:]]*#!?\[' \
        | sort -t: -k1,1 -k2,2n || true
)

echo "== unsafe inventory ($SRC) =="
total=0
while read -r count file; do
    [ -z "$file" ] && continue
    printf '%4d  %s\n' "$count" "$file"
    total=$((total + count))
done < <(printf '%s\n' "$SITES" | cut -d: -f1 | uniq -c | awk 'NF {print $1, $2}')
echo "------"
printf '%4d  total `unsafe` keyword sites\n\n' "$total"

fail=0
# Check each unsafe site for an adjacent SAFETY justification.
while IFS=: read -r file line _; do
    [ -z "$file" ] && continue
    start=$((line - WINDOW))
    [ "$start" -lt 1 ] && start=1
    context=$(sed -n "${start},${line}p" "$file")
    if ! printf '%s\n' "$context" | grep -qiE '(//+ *SAFETY:|//[/!]+ *# Safety)'; then
        echo "MISSING SAFETY comment: $file:$line"
        sed -n "${line}p" "$file" | sed 's/^/    /'
        fail=1
    fi
done <<< "$SITES"

if [ "$fail" -ne 0 ]; then
    echo
    echo "unsafe_audit: FAIL — add a '// SAFETY:' (ops/impls) or '/// # Safety' (fn contracts) justification next to each site."
    exit 1
fi
echo "unsafe_audit: OK — every unsafe site carries a justification."
