#!/usr/bin/env bash
# Audit every `unsafe` site in rust/src for an adjacent justification.
#
# Policy (enforced in the CI lint job):
#   * every line containing the token `unsafe` must have a `// SAFETY:`
#     comment (or a `/// # Safety` contract doc for `unsafe fn`
#     declarations) within the WINDOW lines above it, on it, or — for
#     `unsafe fn` with the doc contract — anywhere in its doc block;
#   * `#![deny(unsafe_op_in_unsafe_fn)]` (lib.rs) makes every unsafe
#     *operation* inside an `unsafe fn` need its own block, so this
#     check covers operations, not just function boundaries.
#
# Output: a per-file inventory of unsafe sites, then a non-zero exit if
# any site lacks a justification.
set -euo pipefail

cd "$(dirname "$0")/.."
SRC=rust/src
WINDOW=6

fail=0
total=0

echo "== unsafe inventory ($SRC) =="
for f in $(grep -rl --include='*.rs' 'unsafe' "$SRC" | sort); do
    count=$(grep -c 'unsafe' "$f" || true)
    printf '%4d  %s\n' "$count" "$f"
    total=$((total + count))
done
echo "------"
printf '%4d  total `unsafe` tokens\n\n' "$total"

# Check each unsafe site for an adjacent SAFETY justification.
while IFS=: read -r file line _; do
    start=$((line - WINDOW))
    [ "$start" -lt 1 ] && start=1
    context=$(sed -n "${start},${line}p" "$file")
    if ! printf '%s\n' "$context" | grep -qiE '(//+ *SAFETY:|//[/!]+ *# Safety)'; then
        echo "MISSING SAFETY comment: $file:$line"
        sed -n "${line}p" "$file" | sed 's/^/    /'
        fail=1
    fi
done < <(grep -rn --include='*.rs' 'unsafe' "$SRC" | grep -vE '^\S+:[0-9]+: *(//|//!|///)([^/]|$)')

if [ "$fail" -ne 0 ]; then
    echo
    echo "unsafe_audit: FAIL — add a '// SAFETY:' (ops/impls) or '/// # Safety' (fn contracts) justification next to each site."
    exit 1
fi
echo "unsafe_audit: OK — every unsafe site carries a justification."
